//! Analytic timing for overlay pipelines (dynamic and static).
//!
//! A placed pipeline of stages `ops` streaming `n` elements costs:
//!
//! * **fill**: Σ stage latencies + 1 cycle per pass-through hop (the time
//!   for the first element to traverse the pipe);
//! * **stream**: `n − 1` further element slots at II = 1 (all library
//!   operators are fully pipelined);
//! * **hops** (static overlay only): the original overlay forwards chunks
//!   store-and-forward at pass-through tiles (operators between
//!   non-contiguous stages re-stage the stream), adding `n` cycles per hop;
//! * **control**: a few cycles per instruction the controller issues.
//!
//! The dynamic overlay's placer guarantees zero hops, so its hop term
//! vanishes — that is Fig. 3's argument in one line.

use crate::bitstream::OperatorKind;
use crate::config::OverlayConfig;

use super::{transfer, TimingBreakdown};

/// Pipelining discipline at pass-through tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardingMode {
    /// Dynamic overlay: hops only delay the pipeline fill.
    Pipelined,
    /// Original static overlay: each hop re-stages the whole stream.
    StoreAndForward,
}

/// Price a pipeline execution.
///
/// * `ops` — pipeline stages in dataflow order;
/// * `n` — elements streamed;
/// * `pass_throughs` — tiles traversed without consumption;
/// * `control_instrs` — controller instructions issued for setup/sequencing;
/// * `input_streams` — DMA'd operand vectors (2 for VMUL&Reduce).
pub fn pipeline_time(
    cfg: &OverlayConfig,
    ops: &[OperatorKind],
    n: usize,
    pass_throughs: usize,
    control_instrs: usize,
    input_streams: usize,
    mode: ForwardingMode,
) -> TimingBreakdown {
    let hz = cfg.clocks.fabric_hz;
    let fill_cycles: u64 =
        ops.iter().map(|o| o.latency_cycles()).sum::<u64>() + pass_throughs as u64;
    let stream_cycles = n.saturating_sub(1) as u64;
    let hop_cycles = match mode {
        ForwardingMode::Pipelined => 0,
        ForwardingMode::StoreAndForward => (pass_throughs * n) as u64,
    };
    TimingBreakdown {
        transfer_s: transfer::pattern_transfer_seconds(&cfg.clocks, input_streams, n),
        fill_s: fill_cycles as f64 / hz,
        stream_s: stream_cycles as f64 / hz,
        hop_s: hop_cycles as f64 / hz,
        control_s: control_instrs as f64 / hz,
    }
}

/// The paper's headline pipeline: VMUL → Reduce.
pub fn vmul_reduce_ops() -> [OperatorKind; 2] {
    [OperatorKind::Mul, OperatorKind::AccSum]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    fn cfg() -> OverlayConfig {
        OverlayConfig::default()
    }

    #[test]
    fn dynamic_ignores_hops_in_steady_state() {
        let c = cfg();
        let t0 = pipeline_time(&c, &vmul_reduce_ops(), 4096, 0, 16, 2, ForwardingMode::Pipelined);
        let t2 = pipeline_time(&c, &vmul_reduce_ops(), 4096, 2, 16, 2, ForwardingMode::Pipelined);
        // two extra fill cycles only
        let delta = t2.total() - t0.total();
        assert!((delta - 2.0 / c.clocks.fabric_hz).abs() < 1e-12);
    }

    #[test]
    fn store_and_forward_pays_per_element() {
        let c = cfg();
        let n = 4096;
        let s1 =
            pipeline_time(&c, &vmul_reduce_ops(), n, 0, 16, 2, ForwardingMode::StoreAndForward);
        let s2 =
            pipeline_time(&c, &vmul_reduce_ops(), n, 1, 16, 2, ForwardingMode::StoreAndForward);
        let s3 =
            pipeline_time(&c, &vmul_reduce_ops(), n, 2, 16, 2, ForwardingMode::StoreAndForward);
        // monotone degradation with pass-through count — Fig. 2/3's shape
        assert!(s1.total() < s2.total());
        assert!(s2.total() < s3.total());
        let per_hop = s2.hop_s - s1.hop_s;
        assert!((per_hop - n as f64 / c.clocks.fabric_hz).abs() < 1e-12);
    }

    #[test]
    fn stream_dominates_fill_for_large_n() {
        let c = cfg();
        let t = pipeline_time(&c, &vmul_reduce_ops(), 65536, 0, 16, 2, ForwardingMode::Pipelined);
        assert!(t.stream_s > 100.0 * t.fill_s);
    }

    #[test]
    fn agrees_with_controller_interpreter() {
        // The analytic fill+stream must match ExecStats::cycles_pipelined's
        // vector component for the same pipeline (same latency/II tables).
        use crate::bitstream::OperatorKind;
        let ops = [OperatorKind::Mul, OperatorKind::AccSum];
        let n = 1000u64;
        let analytic_vec_cycles: u64 = ops.iter().map(|o| o.latency_cycles()).sum::<u64>()
            + 2 * n; // interpreter prices each stage's stream separately
        // (documented equivalence: interpreter counts latency + n per stage)
        let interp: u64 = ops
            .iter()
            .map(|o| o.latency_cycles() + n * o.initiation_interval())
            .sum();
        assert_eq!(analytic_vec_cycles, interp);
    }
}
