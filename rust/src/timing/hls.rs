//! The fully-custom HLS module model.
//!
//! Fig. 3's fifth hardware target is a Vivado-HLS-generated custom
//! accelerator for the same VMUL&Reduce. The paper notes it *"was not
//! optimized, to reflect a closer performance to designs built with HLS by
//! non hardware experts."* Model: a fused II=1 multiply-accumulate pipeline
//! at the fabric clock with a short fill, paying the same DMA transfer as
//! the overlays, derated by an efficiency factor for the un-optimized
//! interface (no burst coalescing, conservative pipelining).

use crate::config::OverlayConfig;

use super::{transfer, TimingBreakdown};

/// Custom-HLS cost model.
#[derive(Debug, Clone, Copy)]
pub struct HlsModel {
    /// Pipeline depth of the fused datapath (fill cycles).
    pub fill_cycles: f64,
    /// Achieved initiation interval (1.0 = perfect; un-optimized HLS
    /// interfaces commonly stall to ~1.5–2 on AXI reads).
    pub initiation_interval: f64,
}

impl Default for HlsModel {
    fn default() -> Self {
        HlsModel { fill_cycles: 12.0, initiation_interval: 1.4 }
    }
}

impl HlsModel {
    /// Price VMUL&Reduce-shaped patterns (`input_streams` operands, fused
    /// single-pass datapath) over `n` elements.
    pub fn pattern_time(
        &self,
        cfg: &OverlayConfig,
        input_streams: usize,
        n: usize,
    ) -> TimingBreakdown {
        let hz = cfg.clocks.fabric_hz;
        TimingBreakdown {
            transfer_s: transfer::pattern_transfer_seconds(&cfg.clocks, input_streams, n),
            fill_s: self.fill_cycles / hz,
            stream_s: n as f64 * self.initiation_interval / hz,
            hop_s: 0.0,
            control_s: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unoptimized_hls_close_to_dynamic_overlay() {
        // Fig. 3: custom HLS and the dynamic overlay are the two fastest
        // series, within ~2× of each other.
        let cfg = OverlayConfig::default();
        let hls = HlsModel::default().pattern_time(&cfg, 2, 4096).total();
        let dyn_ = super::super::overlay::pipeline_time(
            &cfg,
            &super::super::overlay::vmul_reduce_ops(),
            4096,
            0,
            16,
            2,
            super::super::overlay::ForwardingMode::Pipelined,
        )
        .total();
        let ratio = hls / dyn_;
        assert!(ratio > 0.5 && ratio < 2.0, "ratio {ratio}");
    }

    #[test]
    fn transfer_dominates_compute_at_16kb() {
        let cfg = OverlayConfig::default();
        let t = HlsModel::default().pattern_time(&cfg, 2, 4096);
        assert!(t.transfer_s > t.fill_s);
    }
}
