//! The 660 MHz ARM (Zedboard) software reference model.
//!
//! The paper runs the same VMUL&Reduce on the Zynq's ARM core as a software
//! baseline. We model a scalar, non-vectorized loop — the paper's framing
//! is software written by non-hardware-experts, compiled without NEON
//! auto-vectorization (the common -O2 soft-FPU result on that era's
//! toolchains): per element, two loads, a multiply-accumulate, and loop
//! control, dominated by cache-line fills for streaming operands.
//!
//! Calibration: `cycles_per_element` defaults to 24 — consistent with
//! ~27 µs/KB measured for scalar dot products on Zynq-7000 class cores.
//! The workload's values are *computed for real* by [`crate::exec`]'s CPU
//! backend; this module only prices the time.

use crate::config::ClockConfig;

use super::TimingBreakdown;

/// ARM software cost model.
#[derive(Debug, Clone, Copy)]
pub struct ArmModel {
    /// Amortized cycles per streamed element per operator stage.
    pub cycles_per_element: f64,
    /// Fixed call/setup overhead in cycles.
    pub setup_cycles: f64,
}

impl Default for ArmModel {
    fn default() -> Self {
        ArmModel { cycles_per_element: 24.0, setup_cycles: 2_000.0 }
    }
}

impl ArmModel {
    /// Price a `stages`-deep pattern over `n` elements.
    ///
    /// Software touches DDR directly, so there is no fabric DMA term; the
    /// memory traffic cost is folded into `cycles_per_element`.
    pub fn pattern_time(&self, clocks: &ClockConfig, stages: usize, n: usize) -> TimingBreakdown {
        let hz = clocks.arm_hz;
        let compute = self.cycles_per_element * stages.max(1) as f64 * n as f64;
        TimingBreakdown {
            transfer_s: 0.0,
            fill_s: self.setup_cycles / hz,
            stream_s: compute / hz,
            hop_s: 0.0,
            control_s: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_16kb_is_sub_millisecond_but_slow() {
        let m = ArmModel::default();
        let t = m.pattern_time(&ClockConfig::default(), 1, 4096);
        // ~150 µs — the slowest series of Fig. 3 at 16 KB
        assert!(t.total() > 100e-6 && t.total() < 400e-6, "got {}", t.total());
    }

    #[test]
    fn scales_linearly_in_n() {
        let m = ArmModel::default();
        let c = ClockConfig::default();
        let t1 = m.pattern_time(&c, 1, 1024).stream_s;
        let t4 = m.pattern_time(&c, 1, 4096).stream_s;
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn deeper_patterns_cost_more() {
        let m = ArmModel::default();
        let c = ClockConfig::default();
        assert!(m.pattern_time(&c, 3, 4096).total() > m.pattern_time(&c, 1, 4096).total());
    }
}
