//! Controller-program generation for a placed, routed stage pipeline.
//!
//! Emitted program shape (the paper's "series of interpreter instructions"):
//!
//! ```text
//!   ; prologue — one-time fabric assembly
//!   <route interconnect: set.out / bypass.* / set.in>
//!   <pr.connect on every operator tile>
//!   <per-tile constants: chunk size, loop bound>
//!   ; chunked streaming loop (vectors larger than a tile BRAM stream
//!   ; through in BRAM-sized chunks; reduce accumulators carry across)
//! loop:
//!   <dma.in per external/scalar source>
//!   <vec.run / vec.acc per stage, slot-tagged deliveries>
//!   <dma.out of vector results at the current offset>
//!   <advance offsets; cmp; blt loop>
//!   ; epilogue — drain scalar result, halt
//! ```
//!
//! Register conventions (per tile): r0 ≡ 0, r1 = current chunk length,
//! r2 = reduce result, r3 = DDR word offset, r4 = loop bound (stage-0 tile),
//! r5 = chunk constant, r6 = scratch.

use crate::config::OverlayConfig;
use crate::error::{Error, Result};
use crate::isa::{Instr, Opcode, Program};
use crate::patterns::{Composition, Source, Stage};
use crate::place::Placement;
use crate::route::Route;

const R_ZERO: u8 = 0;
const R_LEN: u8 = 1;
const R_ACC: u8 = 2;
const R_OFF: u8 = 3;
const R_BOUND: u8 = 4;
const R_CHUNK: u8 = 5;
const R_SCRATCH: u8 = 6;

/// Generate the controller program.
///
/// Returns `(program, scalar_channel_values, chunk)`.
pub fn generate(
    cfg: &OverlayConfig,
    comp: &Composition,
    stages: &[Stage],
    placement: &Placement,
    routes: &[Route],
) -> Result<(Program, Vec<f32>, usize)> {
    let n = comp.n;
    let chunk = n.min(cfg.bram_words());
    if n % chunk != 0 {
        return Err(Error::Pattern(format!(
            "workload length {n} is not a multiple of the {chunk}-word tile BRAM chunk; \
             pad the input (zero padding is sum-safe for reduce patterns)"
        )));
    }
    if cfg.regs_per_tile <= R_SCRATCH as usize {
        return Err(Error::Config(format!(
            "codegen needs ≥{} registers per tile",
            R_SCRATCH + 1
        )));
    }

    // assign synthetic channels to broadcast scalars (after user inputs)
    let mut scalar_channels: Vec<f32> = Vec::new();
    let mut chan_of_scalar = |v: f32| -> u8 {
        if let Some(k) = scalar_channels.iter().position(|&x| x.to_bits() == v.to_bits()) {
            comp.inputs + k as u8
        } else {
            scalar_channels.push(v);
            comp.inputs + (scalar_channels.len() - 1) as u8
        }
    };

    let tile_of = |stage: usize| -> u8 { placement.assignments[stage].tile as u8 };
    // consumer slot for each producing stage (None = result parked in BRAM)
    let slot_for = |producer: usize| -> Option<u8> {
        for s in stages {
            for src in &s.sources {
                if let Source::Stage { index, slot } = src {
                    if *index == producer {
                        return Some(*slot);
                    }
                }
            }
        }
        None
    };

    let mut p: Vec<Instr> = Vec::with_capacity(64);

    // ---- prologue: interconnect --------------------------------------------
    let mesh = crate::overlay::Mesh::new(cfg.rows, cfg.cols);
    for r in routes {
        p.extend(r.interconnect_instrs(&mesh)?);
    }
    for (i, _) in stages.iter().enumerate() {
        p.push(Instr::op(Opcode::ConnectPr, tile_of(i)));
    }

    // ---- prologue: constants ------------------------------------------------
    let used_tiles: Vec<u8> = {
        let mut v: Vec<u8> = (0..stages.len()).map(&tile_of).collect();
        v.dedup();
        v.sort_unstable();
        v.dedup();
        v
    };
    for &t in &used_tiles {
        emit_const(&mut p, t, R_CHUNK, chunk as i64);
        p.push(Instr { op: Opcode::Mov, tile: t, a: R_LEN, b: R_CHUNK, imm: 0 });
    }
    let t0 = tile_of(0);
    emit_const(&mut p, t0, R_BOUND, n as i64);

    // ---- loop body ------------------------------------------------------------
    let loop_start = p.len();
    for (i, s) in stages.iter().enumerate() {
        let t = tile_of(i);
        // DMA non-stage sources into BRAM0/BRAM1 in source order
        let mut bram_idx: i16 = 0;
        for src in &s.sources {
            match src {
                Source::Stage { .. } => {} // arrives on-fabric
                Source::External { chan } => {
                    p.push(Instr {
                        op: Opcode::DmaIn,
                        tile: t,
                        a: R_LEN,
                        b: R_OFF,
                        imm: ((*chan as i16) << 1) | (bram_idx & 1),
                    });
                    bram_idx += 1;
                }
                Source::Scalar { value_bits } => {
                    let chan = chan_of_scalar(f32::from_bits(*value_bits));
                    p.push(Instr::ldi(t, R_SCRATCH, 1));
                    p.push(Instr {
                        op: Opcode::DmaIn,
                        tile: t,
                        a: R_SCRATCH,
                        b: R_ZERO,
                        imm: ((chan as i16) << 1) | (bram_idx & 1),
                    });
                    bram_idx += 1;
                }
            }
        }
        // the vector op
        if s.is_reduce {
            p.push(Instr { op: Opcode::VecAcc, tile: t, a: R_LEN, b: R_ACC, imm: 0 });
        } else {
            let slot = slot_for(i).unwrap_or(0) as i16;
            p.push(Instr { op: Opcode::VecRun, tile: t, a: R_LEN, b: 0, imm: slot << 1 });
        }
    }

    // drain vector result of the final stage at the current offset
    let last = stages.len() - 1;
    let scalar_result = stages[last].is_reduce;
    if !scalar_result {
        p.push(Instr {
            op: Opcode::DmaOut,
            tile: tile_of(last),
            a: R_LEN,
            b: R_OFF,
            imm: 0, // channel 0, BRAM0
        });
    }

    // advance offsets on every used tile; loop control on stage-0's tile
    for &t in &used_tiles {
        p.push(Instr { op: Opcode::AddR, tile: t, a: R_OFF, b: R_CHUNK, imm: 0 });
    }
    p.push(Instr { op: Opcode::CmpR, tile: t0, a: R_OFF, b: R_BOUND, imm: 0 });
    let here = p.len();
    let delta = loop_start as i64 - here as i64 - 1;
    if delta < -512 {
        return Err(Error::Program(format!(
            "loop body too large for a 10-bit branch offset ({delta})"
        )));
    }
    p.push(Instr { op: Opcode::Blt, tile: t0, a: 0, b: 0, imm: delta as i16 });

    // ---- epilogue: drain the scalar (reduce) result ---------------------------
    if scalar_result {
        let t = tile_of(last);
        p.push(Instr::ldi(t, R_SCRATCH, 1));
        p.push(Instr {
            op: Opcode::DmaOut,
            tile: t,
            a: R_SCRATCH,
            b: R_ZERO,
            imm: 0,
        });
    }
    p.push(Instr::halt());

    let program = Program::new(p, cfg)?;
    Ok((program, scalar_channels, chunk))
}

/// Materialize an arbitrary non-negative constant into `reg` using only
/// 10-bit immediates: binary decomposition with doubling (`ldi` + `add` +
/// `inc`), O(log v) instructions.
fn emit_const(p: &mut Vec<Instr>, tile: u8, reg: u8, v: i64) {
    assert!(v >= 0, "constants are unsigned lengths");
    if v <= 511 {
        p.push(Instr::ldi(tile, reg, v as i16));
        return;
    }
    emit_const(p, tile, reg, v / 2);
    p.push(Instr { op: Opcode::AddR, tile, a: reg, b: reg, imm: 0 }); // reg *= 2
    if v % 2 == 1 {
        p.push(Instr::op_a(Opcode::IncR, tile, reg));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::BitstreamLibrary;
    use crate::config::OverlayConfig;
    use crate::jit::Jit;
    use crate::overlay::Fabric;

    fn compile(comp: &Composition) -> crate::jit::CompiledAccelerator {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        let f = Fabric::new(cfg).unwrap();
        Jit.compile(&f, &lib, comp).unwrap()
    }

    #[test]
    fn emit_const_exact_values() {
        // verify by symbolic execution of the emitted sequence
        for v in [0i64, 1, 511, 512, 1000, 1024, 4096, 65536, 262144, 1_000_000] {
            let mut p = Vec::new();
            emit_const(&mut p, 0, 5, v);
            let mut reg = 0i64;
            for i in &p {
                match i.op {
                    Opcode::Ldi => reg = i.imm as i64,
                    Opcode::AddR => reg *= 2,
                    Opcode::IncR => reg += 1,
                    other => panic!("unexpected {other:?}"),
                }
            }
            assert_eq!(reg, v, "emit_const({v})");
            assert!(p.len() <= 2 * 64, "too long for {v}");
        }
    }

    #[test]
    fn vmul_reduce_program_structure() {
        let acc = compile(&Composition::vmul_reduce(4096));
        let mix = acc.program().category_mix();
        // all four ISA categories are exercised
        assert!(mix.interconnect >= 3, "{mix:?}"); // set.out + set.in + 2×pr.connect
        assert!(mix.vector == 2, "{mix:?}");       // vec.run + vec.acc
        assert!(mix.branch >= 1, "{mix:?}");       // chunk loop
        assert!(mix.mem_reg >= 8, "{mix:?}");
        assert_eq!(acc.chunk(), 1024);
    }

    #[test]
    fn small_workload_single_chunk_no_loop_iterations() {
        let acc = compile(&Composition::vmul_reduce(256));
        assert_eq!(acc.chunk(), 256);
    }

    #[test]
    fn non_multiple_length_rejected() {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        let f = Fabric::new(cfg).unwrap();
        let comp = Composition::vmul_reduce(1500); // 1500 % 1024 != 0
        assert!(Jit.compile(&f, &lib, &comp).is_err());
    }

    #[test]
    fn scalar_channels_deduplicated() {
        // axpy uses one scalar; filter_reduce one; branch one
        let acc = compile(&Composition::axpy(3.5, 512));
        assert_eq!(acc.scalar_channels(), vec![3.5]);
    }

    #[test]
    fn branch_program_has_three_producers_and_select() {
        let acc = compile(&Composition::branch(
            0.0,
            crate::bitstream::OperatorKind::Relu,
            crate::bitstream::OperatorKind::Neg,
            256,
        ));
        let vec_instrs = acc
            .program()
            .instrs()
            .iter()
            .filter(|i| i.op == Opcode::VecRun)
            .count();
        assert_eq!(vec_instrs, 4); // pred, then, else, select
    }

    #[test]
    fn programs_fit_instruction_bram() {
        for comp in [
            Composition::vmul_reduce(262144),
            Composition::filter_reduce(0.1, 65536),
            Composition::map(crate::bitstream::OperatorKind::Sqrt, 4096),
        ] {
            let acc = compile(&comp);
            acc.program().check_bram_fit(&OverlayConfig::default()).unwrap();
        }
    }
}
