//! The JIT: pattern composition → placed, routed, executable accelerator.
//!
//! This is the paper's run-time flow: *"The source code, with symbolic
//! links, is compiled into a series of interpreter instructions executed by
//! the run time system on how to assemble custom bitstream versions of the
//! programming patterns into the PR regions and set the programmable
//! connections of the communication overlay."*
//!
//! Compilation is split into two phases that fail and cache independently:
//!
//!  * **front end** ([`Jit::frontend`]) — fabric-*independent*: linearize
//!    the [`Composition`] into pipeline stages and select a bitstream
//!    region class for each stage. The output [`AcceleratorProgram`] is
//!    valid on every fabric of a config and is what the pool-wide
//!    accelerator cache shares.
//!  * **placement** ([`Jit::place_onto`]) — fabric-*dependent*: place the
//!    stages onto the target fabric's currently-free class-compatible
//!    tiles (contiguous via the dynamic placer; the branch diamond gets a
//!    hub placement), route every on-fabric stream, and codegen the
//!    controller program (interconnect setup, chunked DMA loop, vector
//!    ops, result drain). The output [`PlacementPlan`] is only valid
//!    against the occupancy it was placed against, so the coordinator
//!    caches plans per `(composition, fabric)` and re-runs *this phase
//!    only* when a cached accelerator first lands on a different fabric.
//!
//! [`Jit::compile`] is both phases back to back; [`CompiledAccelerator`]
//! pairs the shared program with one fabric's plan.

pub mod codegen;

use std::sync::Arc;

use crate::bitstream::{BitstreamLibrary, OperatorKind, RegionClass};
use crate::error::{Error, Result};
use crate::isa::Program;
use crate::overlay::Fabric;
use crate::patterns::{Composition, Source, Stage};
use crate::place::{Assignment, DynamicPlacer, Placement};
use crate::route::{shortest_route, Route};

/// The fabric-independent half of a compiled accelerator: what the JIT
/// front end produces before any fabric is chosen. Shared pool-wide.
#[derive(Debug, Clone)]
pub struct AcceleratorProgram {
    pub composition: Composition,
    /// Linearized pipeline stages, in dataflow order.
    pub stages: Vec<Stage>,
    /// Bitstream region class selected for each stage (same order).
    pub classes: Vec<RegionClass>,
    /// [`Composition::cache_key`], precomputed.
    pub key: u64,
}

/// The fabric-dependent half: a placement (plus its routes and the placed
/// controller program) compiled against **one** fabric's occupancy at one
/// point in time. Replaying it elsewhere — or later, after the occupancy
/// moved — may overwrite residents; the engine's residency guard refuses
/// that when free tiles exist, and the coordinator respecializes instead.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    /// Id of the fabric whose occupancy this plan was placed against.
    pub fabric: u64,
    pub placement: Placement,
    pub routes: Vec<Route>,
    pub program: Program,
    /// Broadcast scalars, in the synthetic-channel order codegen assigned
    /// (appended to the user's input channels at execution time).
    pub scalar_channels: Vec<f32>,
    /// Elements per chunk (bounded by the tile data-BRAM capacity).
    pub chunk: usize,
}

impl PlacementPlan {
    /// Total pass-through hops across all routes (0 for dynamic placements
    /// of linear pipelines — the paper's contiguity invariant).
    pub fn total_hops(&self) -> usize {
        self.routes.iter().map(|r| r.hops()).sum()
    }
}

/// A fully compiled accelerator, ready to download + run: the shared
/// program paired with one fabric's placement plan. Cheap to clone (two
/// `Arc`s) — the cache hands these out per request.
#[derive(Debug, Clone)]
pub struct CompiledAccelerator {
    pub spec: Arc<AcceleratorProgram>,
    pub plan: Arc<PlacementPlan>,
}

impl CompiledAccelerator {
    pub fn composition(&self) -> &Composition {
        &self.spec.composition
    }

    pub fn stages(&self) -> &[Stage] {
        &self.spec.stages
    }

    pub fn placement(&self) -> &Placement {
        &self.plan.placement
    }

    pub fn routes(&self) -> &[Route] {
        &self.plan.routes
    }

    pub fn program(&self) -> &Program {
        &self.plan.program
    }

    pub fn scalar_channels(&self) -> &[f32] {
        &self.plan.scalar_channels
    }

    pub fn chunk(&self) -> usize {
        self.plan.chunk
    }

    /// Total pass-through hops across all routes (see
    /// [`PlacementPlan::total_hops`]).
    pub fn total_hops(&self) -> usize {
        self.plan.total_hops()
    }
}

/// The JIT compiler.
#[derive(Debug, Clone, Default)]
pub struct Jit;

impl Jit {
    /// Compile `comp` against `fabric`'s current occupancy: front end plus
    /// placement in one call.
    pub fn compile(
        &self,
        fabric: &Fabric,
        lib: &BitstreamLibrary,
        comp: &Composition,
    ) -> Result<CompiledAccelerator> {
        let spec = Arc::new(self.frontend(lib, comp)?);
        let plan = Arc::new(self.place_onto(fabric, &spec)?);
        Ok(CompiledAccelerator { spec, plan })
    }

    /// Fabric-independent front end: linearize stages and select a
    /// bitstream class per stage (fails fast with a structured error when
    /// an operator has no implementation).
    pub fn frontend(
        &self,
        lib: &BitstreamLibrary,
        comp: &Composition,
    ) -> Result<AcceleratorProgram> {
        let stages = comp.stages();
        if stages.is_empty() {
            return Err(Error::Pattern("composition produced no stages".into()));
        }
        let classes: Vec<RegionClass> =
            stages.iter().map(|s| lib.preferred_class(s.op)).collect::<Result<_>>()?;
        Ok(AcceleratorProgram {
            composition: comp.clone(),
            stages,
            classes,
            key: comp.cache_key(),
        })
    }

    /// Placement-only (re)compile: place `spec`'s stages against `fabric`'s
    /// *current* occupancy, route, and codegen. This is what runs when a
    /// cached accelerator first executes on a fabric other than the one it
    /// was compiled on — or when its own fabric's occupancy drifted under
    /// a cached plan. Needs no bitstream library: the front end already
    /// selected every stage's region class into `spec.classes`.
    pub fn place_onto(&self, fabric: &Fabric, spec: &AcceleratorProgram) -> Result<PlacementPlan> {
        let placement = place_stages(fabric, &spec.stages, &spec.classes)?;
        let routes = route_stages(fabric, &spec.stages, &placement)?;
        let (program, scalar_channels, chunk) = codegen::generate(
            &fabric.cfg,
            &spec.composition,
            &spec.stages,
            &placement,
            &routes,
        )?;
        program.check_bram_fit(&fabric.cfg)?;
        Ok(PlacementPlan {
            fabric: fabric.id,
            placement,
            routes,
            program,
            scalar_channels,
            chunk,
        })
    }
}

/// Place stages: linear pipelines go through the dynamic placer; the
/// branch diamond (a Select consuming three streams) gets a hub-and-spokes
/// placement around a tile with three free neighbours. Both paths consume
/// the front end's per-stage class selection (`classes`) — placement never
/// re-derives it.
fn place_stages(fabric: &Fabric, stages: &[Stage], classes: &[RegionClass]) -> Result<Placement> {
    let select_idx = stages.iter().position(|s| s.op == OperatorKind::Select);
    match select_idx {
        None => {
            let ops: Vec<OperatorKind> = stages.iter().map(|s| s.op).collect();
            DynamicPlacer.place_with_needs(fabric, &ops, classes)
        }
        Some(sel) => place_diamond(fabric, stages, classes, sel),
    }
}

fn place_diamond(
    fabric: &Fabric,
    stages: &[Stage],
    classes: &[RegionClass],
    sel: usize,
) -> Result<Placement> {
    // producers feeding the select, in slot order
    let producers: Vec<usize> = stages[sel]
        .sources
        .iter()
        .map(|s| match s {
            Source::Stage { index, .. } => Ok(*index),
            _ => Err(Error::Pattern("select sources must be stages".into())),
        })
        .collect::<Result<_>>()?;

    let free = |t: usize| fabric.tiles[t].resident.is_none();
    let class_ok = |t: usize, need: RegionClass| -> bool {
        match need {
            RegionClass::Large => fabric.tiles[t].class == RegionClass::Large,
            RegionClass::Small => true,
        }
    };

    // hub: a free, select-compatible tile with enough free neighbours to
    // host every producer (greedy matching, producers with large-region
    // needs assigned first).
    for hub in 0..fabric.tiles.len() {
        if !free(hub) || !class_ok(hub, classes[sel]) {
            continue;
        }
        let mut neigh: Vec<usize> = crate::isa::Dir::ALL
            .into_iter()
            .filter_map(|d| fabric.mesh.neighbor(hub, d))
            .filter(|&t| free(t))
            .collect();
        if neigh.len() < producers.len() {
            continue;
        }
        // assign large-needing producers first
        let mut order: Vec<usize> = producers.clone();
        order.sort_by_key(|&p| std::cmp::Reverse(classes[p] == RegionClass::Large));
        let mut chosen: std::collections::HashMap<usize, usize> = Default::default();
        let mut ok = true;
        for p in order {
            let pos = neigh.iter().position(|&t| class_ok(t, classes[p]));
            match pos {
                Some(k) => {
                    chosen.insert(p, neigh.remove(k));
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        // build assignments in stage order
        let mut assignments = Vec::with_capacity(stages.len());
        for (i, s) in stages.iter().enumerate() {
            let tile = if i == sel {
                hub
            } else if let Some(&t) = chosen.get(&i) {
                t
            } else {
                return Err(Error::Placement(
                    "diamond placement only supports pred/then/else/select stages".into(),
                ));
            };
            assignments.push(Assignment { op: s.op, tile, class: fabric.tiles[tile].class });
        }
        return Ok(Placement { assignments });
    }
    Err(Error::Placement(
        "no hub tile with enough free class-compatible neighbours for the branch diamond".into(),
    ))
}

/// Route every `Source::Stage` edge of the pipeline.
fn route_stages(fabric: &Fabric, stages: &[Stage], placement: &Placement) -> Result<Vec<Route>> {
    // tiles that consume (host operators) block pass-through routing
    let mut blocked = vec![false; fabric.tiles.len()];
    for a in &placement.assignments {
        blocked[a.tile] = true;
    }
    // previously-occupied tiles block too
    for (t, tile) in fabric.tiles.iter().enumerate() {
        if tile.resident.is_some() {
            blocked[t] = true;
        }
    }

    let mut routes = Vec::new();
    for (i, s) in stages.iter().enumerate() {
        for src in &s.sources {
            if let Source::Stage { index, .. } = src {
                let from = placement.tile_of(*index).ok_or_else(|| {
                    Error::Placement(format!("stage {index} missing from placement"))
                })?;
                let to = placement
                    .tile_of(i)
                    .ok_or_else(|| Error::Placement(format!("stage {i} missing")))?;
                routes.push(shortest_route(&fabric.mesh, from, to, &blocked)?);
            }
        }
    }
    Ok(routes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    fn setup() -> (Fabric, BitstreamLibrary) {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        (Fabric::new(cfg).unwrap(), lib)
    }

    #[test]
    fn vmul_reduce_compiles_contiguous() {
        let (f, lib) = setup();
        let acc = Jit.compile(&f, &lib, &Composition::vmul_reduce(4096)).unwrap();
        assert_eq!(acc.stages().len(), 2);
        assert_eq!(acc.total_hops(), 0, "dynamic overlay must be contiguous");
        assert!(acc.placement().is_injective());
        assert!(acc.program().len() > 5);
    }

    #[test]
    fn chain_compiles() {
        let (f, lib) = setup();
        let comp =
            Composition::chain(&[OperatorKind::Abs, OperatorKind::Sqrt, OperatorKind::Log], 1024)
                .unwrap();
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        assert_eq!(acc.stages().len(), 3);
        // sqrt & log need the two large tiles; abs can sit anywhere —
        // at most one skipped tile between stages.
        assert!(acc.total_hops() <= 2, "hops: {}", acc.total_hops());
    }

    #[test]
    fn branch_places_as_diamond() {
        let (f, lib) = setup();
        let comp = Composition::branch(0.0, OperatorKind::Relu, OperatorKind::Neg, 512);
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        assert_eq!(acc.stages().len(), 4);
        // all three producers adjacent to the select hub
        assert_eq!(acc.total_hops(), 0);
        let sel_tile = acc.placement().assignments[3].tile;
        for a in &acc.placement().assignments[..3] {
            assert_eq!(f.mesh.manhattan(a.tile, sel_tile), 1);
        }
    }

    #[test]
    fn branch_with_large_arms_places() {
        let (f, lib) = setup();
        let comp = Composition::branch(0.5, OperatorKind::Sqrt, OperatorKind::Square, 256);
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        let sqrt_stage = acc
            .placement()
            .assignments
            .iter()
            .find(|a| a.op == OperatorKind::Sqrt)
            .unwrap();
        assert_eq!(sqrt_stage.class, RegionClass::Large);
    }

    #[test]
    fn occupied_fabric_reduces_capacity() {
        let (mut f, lib) = setup();
        // occupy 8 of 9 tiles
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        let bl = lib.get(OperatorKind::Add, RegionClass::Large).unwrap().clone();
        for t in 0..8 {
            let b = if f.cfg.is_large_tile(t) { &bl } else { &bs };
            f.load_bitstream(t, b).unwrap();
        }
        let err = Jit.compile(&f, &lib, &Composition::vmul_reduce(64)).unwrap_err();
        assert!(err.is_capacity());
    }

    #[test]
    fn scalar_channels_surface_in_accelerator() {
        let (f, lib) = setup();
        let acc = Jit.compile(&f, &lib, &Composition::filter_reduce(0.75, 512)).unwrap();
        assert_eq!(acc.scalar_channels(), &[0.75]);
    }

    /// The split itself: the front end is fabric-blind, and placement-only
    /// recompiles land on whatever tiles the target fabric has free.
    #[test]
    fn place_onto_respects_target_occupancy() {
        let (f_empty, lib) = setup();
        let comp = Composition::vmul_reduce(256);
        let spec = Arc::new(Jit.frontend(&lib, &comp).unwrap());
        assert_eq!(spec.key, comp.cache_key());
        assert_eq!(spec.stages.len(), spec.classes.len());
        assert!(spec.classes.iter().all(|c| *c == RegionClass::Small));

        let plan_a = Jit.place_onto(&f_empty, &spec).unwrap();
        assert_eq!(plan_a.fabric, f_empty.id);

        // a second fabric whose first snake tile is occupied
        let (mut f_busy, _) = setup();
        let bs = lib.get(OperatorKind::Abs, RegionClass::Small).unwrap().clone();
        f_busy.load_bitstream(0, &bs).unwrap();
        let plan_b = Jit.place_onto(&f_busy, &spec).unwrap();
        assert_eq!(plan_b.fabric, f_busy.id);
        assert_ne!(plan_a.fabric, plan_b.fabric);
        assert!(
            plan_b.placement.assignments.iter().all(|a| a.tile != 0),
            "respecialized placement must avoid the occupied tile: {:?}",
            plan_b.placement.assignments
        );
        // both plans realize the same program shape (placement-only phase)
        assert_eq!(plan_a.chunk, plan_b.chunk);
        assert_eq!(plan_a.scalar_channels, plan_b.scalar_channels);
    }
}
