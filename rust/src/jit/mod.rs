//! The JIT: pattern composition → placed, routed, executable accelerator.
//!
//! This is the paper's run-time flow: *"The source code, with symbolic
//! links, is compiled into a series of interpreter instructions executed by
//! the run time system on how to assemble custom bitstream versions of the
//! programming patterns into the PR regions and set the programmable
//! connections of the communication overlay."*
//!
//! [`Jit::compile`] performs, in order:
//!  1. **linearize** the [`Composition`] into pipeline stages;
//!  2. **select** a bitstream for each stage from the library;
//!  3. **place** stages onto free class-compatible tiles (contiguous via
//!     the dynamic placer; the branch diamond gets a hub placement);
//!  4. **route** every on-fabric stream between stages;
//!  5. **codegen** the controller program (interconnect setup, chunked DMA
//!     loop, vector ops, result drain).
//!
//! The output [`CompiledAccelerator`] carries everything the execution
//! engine and the reconfiguration manager need.

pub mod codegen;


use crate::bitstream::{BitstreamLibrary, OperatorKind, RegionClass};

use crate::error::{Error, Result};
use crate::isa::Program;
use crate::overlay::Fabric;
use crate::patterns::{Composition, Source, Stage};
use crate::place::{Assignment, DynamicPlacer, Placement};
use crate::route::{shortest_route, Route};

/// A fully compiled accelerator, ready to download + run.
#[derive(Debug, Clone)]
pub struct CompiledAccelerator {
    pub composition: Composition,
    pub stages: Vec<Stage>,
    pub placement: Placement,
    pub routes: Vec<Route>,
    pub program: Program,
    /// Broadcast scalars, in the synthetic-channel order codegen assigned
    /// (appended to the user's input channels at execution time).
    pub scalar_channels: Vec<f32>,
    /// Elements per chunk (bounded by the tile data-BRAM capacity).
    pub chunk: usize,
}

impl CompiledAccelerator {
    /// Total pass-through hops across all routes (0 for dynamic placements
    /// of linear pipelines — the paper's contiguity invariant).
    pub fn total_hops(&self) -> usize {
        self.routes.iter().map(|r| r.hops()).sum()
    }
}

/// The JIT compiler.
#[derive(Debug, Clone, Default)]
pub struct Jit;

impl Jit {
    /// Compile `comp` against the current fabric occupancy.
    pub fn compile(
        &self,
        fabric: &Fabric,
        lib: &BitstreamLibrary,
        comp: &Composition,
    ) -> Result<CompiledAccelerator> {
        let stages = comp.stages();
        if stages.is_empty() {
            return Err(Error::Pattern("composition produced no stages".into()));
        }
        // bitstream selection feasibility (fail fast with a structured error)
        for s in &stages {
            lib.preferred_class(s.op)?;
        }

        let placement = place_stages(fabric, lib, &stages)?;
        let routes = route_stages(fabric, &stages, &placement)?;
        let (program, scalar_channels, chunk) =
            codegen::generate(&fabric.cfg, comp, &stages, &placement, &routes)?;
        program.check_bram_fit(&fabric.cfg)?;

        Ok(CompiledAccelerator {
            composition: comp.clone(),
            stages,
            placement,
            routes,
            program,
            scalar_channels,
            chunk,
        })
    }
}

/// Place stages: linear pipelines go through the dynamic placer; the branch
/// diamond (a Select consuming three streams) gets a hub-and-spokes
/// placement around a tile with three free neighbours.
fn place_stages(
    fabric: &Fabric,
    lib: &BitstreamLibrary,
    stages: &[Stage],
) -> Result<Placement> {
    let select_idx = stages.iter().position(|s| s.op == OperatorKind::Select);
    match select_idx {
        None => {
            let ops: Vec<OperatorKind> = stages.iter().map(|s| s.op).collect();
            DynamicPlacer.place(fabric, lib, &ops)
        }
        Some(sel) => place_diamond(fabric, lib, stages, sel),
    }
}

fn place_diamond(
    fabric: &Fabric,
    lib: &BitstreamLibrary,
    stages: &[Stage],
    sel: usize,
) -> Result<Placement> {
    // producers feeding the select, in slot order
    let producers: Vec<usize> = stages[sel]
        .sources
        .iter()
        .map(|s| match s {
            Source::Stage { index, .. } => Ok(*index),
            _ => Err(Error::Pattern("select sources must be stages".into())),
        })
        .collect::<Result<_>>()?;

    let free = |t: usize| fabric.tiles[t].resident.is_none();
    let class_ok = |t: usize, op: OperatorKind| -> bool {
        match lib.preferred_class(op) {
            Ok(RegionClass::Large) => fabric.tiles[t].class == RegionClass::Large,
            Ok(RegionClass::Small) => true,
            Err(_) => false,
        }
    };

    // hub: a free, select-compatible tile with enough free neighbours to
    // host every producer (greedy matching, producers with large-region
    // needs assigned first).
    for hub in 0..fabric.tiles.len() {
        if !free(hub) || !class_ok(hub, OperatorKind::Select) {
            continue;
        }
        let mut neigh: Vec<usize> = crate::isa::Dir::ALL
            .into_iter()
            .filter_map(|d| fabric.mesh.neighbor(hub, d))
            .filter(|&t| free(t))
            .collect();
        if neigh.len() < producers.len() {
            continue;
        }
        // assign large-needing producers first
        let mut order: Vec<usize> = producers.clone();
        order.sort_by_key(|&p| {
            std::cmp::Reverse(matches!(
                lib.preferred_class(stages[p].op),
                Ok(RegionClass::Large)
            ))
        });
        let mut chosen: std::collections::HashMap<usize, usize> = Default::default();
        let mut ok = true;
        for p in order {
            let pos = neigh.iter().position(|&t| class_ok(t, stages[p].op));
            match pos {
                Some(k) => {
                    chosen.insert(p, neigh.remove(k));
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        // build assignments in stage order
        let mut assignments = Vec::with_capacity(stages.len());
        for (i, s) in stages.iter().enumerate() {
            let tile = if i == sel {
                hub
            } else if let Some(&t) = chosen.get(&i) {
                t
            } else {
                return Err(Error::Placement(
                    "diamond placement only supports pred/then/else/select stages".into(),
                ));
            };
            assignments.push(Assignment { op: s.op, tile, class: fabric.tiles[tile].class });
        }
        return Ok(Placement { assignments });
    }
    Err(Error::Placement(
        "no hub tile with enough free class-compatible neighbours for the branch diamond".into(),
    ))
}

/// Route every `Source::Stage` edge of the pipeline.
fn route_stages(
    fabric: &Fabric,
    stages: &[Stage],
    placement: &Placement,
) -> Result<Vec<Route>> {
    // tiles that consume (host operators) block pass-through routing
    let mut blocked = vec![false; fabric.tiles.len()];
    for a in &placement.assignments {
        blocked[a.tile] = true;
    }
    // previously-occupied tiles block too
    for (t, tile) in fabric.tiles.iter().enumerate() {
        if tile.resident.is_some() {
            blocked[t] = true;
        }
    }

    let mut routes = Vec::new();
    for (i, s) in stages.iter().enumerate() {
        for src in &s.sources {
            if let Source::Stage { index, .. } = src {
                let from = placement.tile_of(*index).ok_or_else(|| {
                    Error::Placement(format!("stage {index} missing from placement"))
                })?;
                let to = placement
                    .tile_of(i)
                    .ok_or_else(|| Error::Placement(format!("stage {i} missing")))?;
                routes.push(shortest_route(&fabric.mesh, from, to, &blocked)?);
            }
        }
    }
    Ok(routes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    fn setup() -> (Fabric, BitstreamLibrary) {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        (Fabric::new(cfg).unwrap(), lib)
    }

    #[test]
    fn vmul_reduce_compiles_contiguous() {
        let (f, lib) = setup();
        let acc = Jit.compile(&f, &lib, &Composition::vmul_reduce(4096)).unwrap();
        assert_eq!(acc.stages.len(), 2);
        assert_eq!(acc.total_hops(), 0, "dynamic overlay must be contiguous");
        assert!(acc.placement.is_injective());
        assert!(acc.program.len() > 5);
    }

    #[test]
    fn chain_compiles() {
        let (f, lib) = setup();
        let comp = Composition::chain(
            &[OperatorKind::Abs, OperatorKind::Sqrt, OperatorKind::Log],
            1024,
        )
        .unwrap();
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        assert_eq!(acc.stages.len(), 3);
        // sqrt & log need the two large tiles; abs can sit anywhere —
        // at most one skipped tile between stages.
        assert!(acc.total_hops() <= 2, "hops: {}", acc.total_hops());
    }

    #[test]
    fn branch_places_as_diamond() {
        let (f, lib) = setup();
        let comp = Composition::branch(0.0, OperatorKind::Relu, OperatorKind::Neg, 512);
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        assert_eq!(acc.stages.len(), 4);
        // all three producers adjacent to the select hub
        assert_eq!(acc.total_hops(), 0);
        let sel_tile = acc.placement.assignments[3].tile;
        for a in &acc.placement.assignments[..3] {
            assert_eq!(f.mesh.manhattan(a.tile, sel_tile), 1);
        }
    }

    #[test]
    fn branch_with_large_arms_places() {
        let (f, lib) = setup();
        let comp = Composition::branch(0.5, OperatorKind::Sqrt, OperatorKind::Square, 256);
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        let sqrt_stage = acc
            .placement
            .assignments
            .iter()
            .find(|a| a.op == OperatorKind::Sqrt)
            .unwrap();
        assert_eq!(sqrt_stage.class, RegionClass::Large);
    }

    #[test]
    fn occupied_fabric_reduces_capacity() {
        let (mut f, lib) = setup();
        // occupy 8 of 9 tiles
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        let bl = lib.get(OperatorKind::Add, RegionClass::Large).unwrap().clone();
        for t in 0..8 {
            let b = if f.cfg.is_large_tile(t) { &bl } else { &bs };
            f.load_bitstream(t, b).unwrap();
        }
        let err = Jit.compile(&f, &lib, &Composition::vmul_reduce(64)).unwrap_err();
        assert!(err.is_capacity());
    }

    #[test]
    fn scalar_channels_surface_in_accelerator() {
        let (f, lib) = setup();
        let acc = Jit.compile(&f, &lib, &Composition::filter_reduce(0.75, 512)).unwrap();
        assert_eq!(acc.scalar_channels, vec![0.75]);
    }
}
