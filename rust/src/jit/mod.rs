//! The JIT: pattern composition → placed, routed, executable accelerator.
//!
//! This is the paper's run-time flow: *"The source code, with symbolic
//! links, is compiled into a series of interpreter instructions executed by
//! the run time system on how to assemble custom bitstream versions of the
//! programming patterns into the PR regions and set the programmable
//! connections of the communication overlay."*
//!
//! Compilation is split into two phases that fail and cache independently:
//!
//!  * **front end** ([`Jit::frontend`]) — fabric-*independent*: linearize
//!    the [`Composition`] into pipeline stages and select a bitstream
//!    region class for each stage. The output [`AcceleratorProgram`] is
//!    valid on every fabric of a config and is what the pool-wide
//!    accelerator cache shares.
//!  * **placement** ([`Jit::place_onto`]) — fabric-*dependent*: place the
//!    stages onto the target fabric's currently-free class-compatible
//!    tiles (contiguous via the dynamic placer; the branch diamond gets a
//!    hub placement), route every on-fabric stream, and codegen the
//!    controller program (interconnect setup, chunked DMA loop, vector
//!    ops, result drain). The output [`PlacementPlan`] is only valid
//!    against the occupancy it was placed against, so the coordinator
//!    caches plans per `(composition, fabric)` and re-runs *this phase
//!    only* when a cached accelerator first lands on a different fabric.
//!
//! [`Jit::compile`] is both phases back to back; [`CompiledAccelerator`]
//! pairs the shared program with one fabric's plan.

pub mod codegen;

use std::sync::Arc;

use crate::bitstream::{BitstreamLibrary, Footprint, OperatorKind, RegionClass};
use crate::error::{Error, Result};
use crate::isa::Program;
use crate::overlay::Fabric;
use crate::patterns::{Composition, Source, Stage};
use crate::place::{Assignment, DynamicPlacer, Placement};
use crate::route::{shortest_route, Route};

/// Salt XOR'd into [`AcceleratorProgram::key`] when the front end runs with
/// fusion enabled, so fused and unfused compiles of the same composition
/// never collide in the accelerator cache.
pub const FUSED_KEY_SALT: u64 = 0xA5F0_5EDC_0DE5_A17E;

/// The fabric-independent half of a compiled accelerator: what the JIT
/// front end produces before any fabric is chosen. Shared pool-wide.
#[derive(Debug, Clone)]
pub struct AcceleratorProgram {
    pub composition: Composition,
    /// Linearized pipeline stages, in dataflow order.
    pub stages: Vec<Stage>,
    /// Bitstream region class selected for each stage (same order).
    pub classes: Vec<RegionClass>,
    /// [`Composition::cache_key`], precomputed — XOR'd with
    /// [`FUSED_KEY_SALT`] when compiled by [`Jit::frontend_with`] with
    /// fusion on.
    pub key: u64,
    /// Stage pairs the fusion pass collapsed (0 when fusion was off or
    /// found nothing fusible).
    pub fused_pairs: usize,
}

/// The fabric-dependent half: a placement (plus its routes and the placed
/// controller program) compiled against **one** fabric's occupancy at one
/// point in time. Replaying it elsewhere — or later, after the occupancy
/// moved — may overwrite residents; the engine's residency guard refuses
/// that when free tiles exist, and the coordinator respecializes instead.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    /// Id of the fabric whose occupancy this plan was placed against.
    pub fabric: u64,
    pub placement: Placement,
    pub routes: Vec<Route>,
    pub program: Program,
    /// Broadcast scalars, in the synthetic-channel order codegen assigned
    /// (appended to the user's input channels at execution time).
    pub scalar_channels: Vec<f32>,
    /// Elements per chunk (bounded by the tile data-BRAM capacity).
    pub chunk: usize,
}

impl PlacementPlan {
    /// Total pass-through hops across all routes (0 for dynamic placements
    /// of linear pipelines — the paper's contiguity invariant).
    pub fn total_hops(&self) -> usize {
        self.routes.iter().map(|r| r.hops()).sum()
    }
}

/// A fully compiled accelerator, ready to download + run: the shared
/// program paired with one fabric's placement plan. Cheap to clone (two
/// `Arc`s) — the cache hands these out per request.
#[derive(Debug, Clone)]
pub struct CompiledAccelerator {
    pub spec: Arc<AcceleratorProgram>,
    pub plan: Arc<PlacementPlan>,
}

impl CompiledAccelerator {
    pub fn composition(&self) -> &Composition {
        &self.spec.composition
    }

    pub fn stages(&self) -> &[Stage] {
        &self.spec.stages
    }

    pub fn placement(&self) -> &Placement {
        &self.plan.placement
    }

    pub fn routes(&self) -> &[Route] {
        &self.plan.routes
    }

    pub fn program(&self) -> &Program {
        &self.plan.program
    }

    pub fn scalar_channels(&self) -> &[f32] {
        &self.plan.scalar_channels
    }

    pub fn chunk(&self) -> usize {
        self.plan.chunk
    }

    /// Total pass-through hops across all routes (see
    /// [`PlacementPlan::total_hops`]).
    pub fn total_hops(&self) -> usize {
        self.plan.total_hops()
    }
}

/// The JIT compiler.
#[derive(Debug, Clone, Default)]
pub struct Jit;

impl Jit {
    /// Compile `comp` against `fabric`'s current occupancy: front end plus
    /// placement in one call.
    pub fn compile(
        &self,
        fabric: &Fabric,
        lib: &BitstreamLibrary,
        comp: &Composition,
    ) -> Result<CompiledAccelerator> {
        self.compile_with(fabric, lib, comp, false)
    }

    /// [`Jit::compile`] with an explicit fusion policy.
    pub fn compile_with(
        &self,
        fabric: &Fabric,
        lib: &BitstreamLibrary,
        comp: &Composition,
        fuse: bool,
    ) -> Result<CompiledAccelerator> {
        let spec = Arc::new(self.frontend_with(lib, comp, fuse)?);
        let plan = Arc::new(self.place_onto(fabric, &spec)?);
        Ok(CompiledAccelerator { spec, plan })
    }

    /// Fabric-independent front end: linearize stages and select a
    /// bitstream class per stage (fails fast with a structured error when
    /// an operator has no implementation).
    pub fn frontend(
        &self,
        lib: &BitstreamLibrary,
        comp: &Composition,
    ) -> Result<AcceleratorProgram> {
        self.frontend_with(lib, comp, false)
    }

    /// [`Jit::frontend`] with an explicit fusion policy. With `fuse` on,
    /// adjacent map∘map and map∘reduce stage pairs whose combined footprint
    /// fits a region class collapse into single fused stages — fewer tiles,
    /// fewer PR downloads, identical results (the tail applies element-wise
    /// inside the tile). The cache key is salted so the two policies never
    /// share cache entries.
    pub fn frontend_with(
        &self,
        lib: &BitstreamLibrary,
        comp: &Composition,
        fuse: bool,
    ) -> Result<AcceleratorProgram> {
        let stages = comp.stages();
        if stages.is_empty() {
            return Err(Error::Pattern("composition produced no stages".into()));
        }
        let (stages, classes, fused_pairs) = if fuse {
            fuse_stages(lib, stages)?
        } else {
            let classes: Vec<RegionClass> =
                stages.iter().map(|s| lib.preferred_class(s.op)).collect::<Result<_>>()?;
            (stages, classes, 0)
        };
        Ok(AcceleratorProgram {
            composition: comp.clone(),
            stages,
            classes,
            key: comp.cache_key() ^ if fuse { FUSED_KEY_SALT } else { 0 },
            fused_pairs,
        })
    }

    /// Placement-only (re)compile: place `spec`'s stages against `fabric`'s
    /// *current* occupancy, route, and codegen. This is what runs when a
    /// cached accelerator first executes on a fabric other than the one it
    /// was compiled on — or when its own fabric's occupancy drifted under
    /// a cached plan. Needs no bitstream library: the front end already
    /// selected every stage's region class into `spec.classes`.
    pub fn place_onto(&self, fabric: &Fabric, spec: &AcceleratorProgram) -> Result<PlacementPlan> {
        let mut placement = place_stages(fabric, &spec.stages, &spec.classes)?;
        // both placers emit assignments in stage order; carry each stage's
        // fused tail into its assignment so the PR manager downloads the
        // fused bitstream (and residency tracks the pair, not just the head)
        for (a, s) in placement.assignments.iter_mut().zip(&spec.stages) {
            a.tail = s.fused;
        }
        let routes = route_stages(fabric, &spec.stages, &placement)?;
        let (program, scalar_channels, chunk) = codegen::generate(
            &fabric.cfg,
            &spec.composition,
            &spec.stages,
            &placement,
            &routes,
        )?;
        program.check_bram_fit(&fabric.cfg)?;
        Ok(PlacementPlan {
            fabric: fabric.id,
            placement,
            routes,
            program,
            scalar_channels,
            chunk,
        })
    }

    /// Re-plan `spec` against a **fixed** placement: route and codegen
    /// only, no placer. This is the compactor's republish path — after a
    /// migration moved residents, the cached plan's assignments are
    /// remapped tile-for-tile and the routes/program regenerated here, so
    /// the next request replays onto the tiles the residents actually
    /// occupy instead of re-downloading into the vacated ones. Unlike
    /// [`Jit::place_onto`], the placement's tiles may already host their
    /// own operators (that is the point); routing still refuses to pass
    /// through any occupied tile. Fails (e.g. no route between
    /// non-adjacent stages) without side effects — the caller then keeps
    /// the old plan and lets the engine's staleness guard respecialize on
    /// demand.
    pub fn plan_for_placement(
        &self,
        fabric: &Fabric,
        spec: &AcceleratorProgram,
        placement: Placement,
    ) -> Result<PlacementPlan> {
        if placement.assignments.len() != spec.stages.len() {
            return Err(Error::Placement(format!(
                "fixed placement has {} assignments for {} stages",
                placement.assignments.len(),
                spec.stages.len()
            )));
        }
        let routes = route_stages(fabric, &spec.stages, &placement)?;
        let (program, scalar_channels, chunk) = codegen::generate(
            &fabric.cfg,
            &spec.composition,
            &spec.stages,
            &placement,
            &routes,
        )?;
        program.check_bram_fit(&fabric.cfg)?;
        Ok(PlacementPlan {
            fabric: fabric.id,
            placement,
            routes,
            program,
            scalar_channels,
            chunk,
        })
    }
}

/// The fusion pass: one left-to-right scan collapsing adjacent (producer,
/// consumer) stage pairs into single fused stages.
///
/// A pair `(a, b)` fuses when every one of these holds:
///
///  * `b`'s only input is `a`'s stream (slot 0), and `b` is `a`'s only
///    consumer — fusing must not steal a stream someone else reads;
///  * `a` is a plain map (not a reduce, not stateful, not `Select`/`Route`);
///  * `b` is either the reduce stage (a stateful fold — map∘reduce fusion,
///    e.g. `mul+acc_sum`) or a unary stateless map (map∘map fusion);
///  * the combined footprint fits *some* region class — the resource-aware
///    gate: `neg+abs` shares a Small region, `square+relu` needs Large,
///    `sin+exp` fuses nowhere and stays two tiles.
///
/// The fused stage keeps `a`'s operator and sources, takes `b`'s reduce
/// role, and records `b`'s operator as its tail; later stage references are
/// remapped over the removed index. Fused stages never re-fuse (pair-only —
/// region budgets rarely hold three datapaths, and pairs keep residency
/// churn analyzable).
///
/// Returns the rewritten stages, their region classes (fused stages get the
/// smallest class holding the *combined* footprint), and the pair count.
fn fuse_stages(
    lib: &BitstreamLibrary,
    mut stages: Vec<Stage>,
) -> Result<(Vec<Stage>, Vec<RegionClass>, usize)> {
    fn can_fuse(stages: &[Stage], i: usize) -> bool {
        let (a, b) = (&stages[i], &stages[i + 1]);
        if a.fused.is_some() || b.fused.is_some() {
            return false;
        }
        if a.is_reduce || a.op.is_stateful() {
            return false;
        }
        if matches!(a.op, OperatorKind::Select | OperatorKind::Route)
            || matches!(b.op, OperatorKind::Select | OperatorKind::Route)
        {
            return false;
        }
        if b.sources.len() != 1 || b.sources[0] != (Source::Stage { index: i, slot: 0 }) {
            return false;
        }
        let other_consumer = stages.iter().enumerate().any(|(k, s)| {
            k != i + 1
                && s.sources
                    .iter()
                    .any(|src| matches!(src, Source::Stage { index, .. } if *index == i))
        });
        if other_consumer {
            return false;
        }
        let tail_ok = if b.is_reduce {
            b.op.is_stateful()
        } else {
            b.op.arity() == 1 && !b.op.is_stateful()
        };
        if !tail_ok {
            return false;
        }
        let fp = Footprint::for_operator(a.op).plus(&Footprint::for_operator(b.op));
        RegionClass::smallest_fitting(&fp).is_some()
    }

    let mut fused_pairs = 0;
    let mut i = 0;
    while i + 1 < stages.len() {
        if can_fuse(&stages, i) {
            let b = stages.remove(i + 1);
            stages[i].fused = Some(b.op);
            stages[i].is_reduce = b.is_reduce;
            fused_pairs += 1;
            // close the index gap left by `b`
            for s in stages.iter_mut() {
                for src in s.sources.iter_mut() {
                    if let Source::Stage { index, .. } = src {
                        if *index == i + 1 {
                            *index = i;
                        } else if *index > i + 1 {
                            *index -= 1;
                        }
                    }
                }
            }
        }
        i += 1;
    }

    let classes: Vec<RegionClass> = stages
        .iter()
        .map(|s| match s.fused {
            Some(t) => {
                let fp = Footprint::for_operator(s.op).plus(&Footprint::for_operator(t));
                RegionClass::smallest_fitting(&fp).ok_or_else(|| {
                    Error::Pattern(format!(
                        "fused {}+{} fits no region class",
                        s.op.name(),
                        t.name()
                    ))
                })
            }
            None => lib.preferred_class(s.op),
        })
        .collect::<Result<_>>()?;
    Ok((stages, classes, fused_pairs))
}

/// Place stages: linear pipelines go through the dynamic placer; the
/// branch diamond (a Select consuming three streams) gets a hub-and-spokes
/// placement around a tile with three free neighbours. Both paths consume
/// the front end's per-stage class selection (`classes`) — placement never
/// re-derives it.
fn place_stages(fabric: &Fabric, stages: &[Stage], classes: &[RegionClass]) -> Result<Placement> {
    let select_idx = stages.iter().position(|s| s.op == OperatorKind::Select);
    match select_idx {
        None => {
            let ops: Vec<OperatorKind> = stages.iter().map(|s| s.op).collect();
            DynamicPlacer.place_with_needs(fabric, &ops, classes)
        }
        Some(sel) => place_diamond(fabric, stages, classes, sel),
    }
}

fn place_diamond(
    fabric: &Fabric,
    stages: &[Stage],
    classes: &[RegionClass],
    sel: usize,
) -> Result<Placement> {
    // producers feeding the select, in slot order
    let producers: Vec<usize> = stages[sel]
        .sources
        .iter()
        .map(|s| match s {
            Source::Stage { index, .. } => Ok(*index),
            _ => Err(Error::Pattern("select sources must be stages".into())),
        })
        .collect::<Result<_>>()?;

    let free = |t: usize| fabric.tiles[t].resident.is_none();
    let class_ok = |t: usize, need: RegionClass| -> bool {
        match need {
            RegionClass::Large => fabric.tiles[t].class == RegionClass::Large,
            RegionClass::Small => true,
        }
    };

    // hub: a free, select-compatible tile with enough free neighbours to
    // host every producer (greedy matching, producers with large-region
    // needs assigned first).
    for hub in 0..fabric.tiles.len() {
        if !free(hub) || !class_ok(hub, classes[sel]) {
            continue;
        }
        let mut neigh: Vec<usize> = crate::isa::Dir::ALL
            .into_iter()
            .filter_map(|d| fabric.mesh.neighbor(hub, d))
            .filter(|&t| free(t))
            .collect();
        if neigh.len() < producers.len() {
            continue;
        }
        // assign large-needing producers first
        let mut order: Vec<usize> = producers.clone();
        order.sort_by_key(|&p| std::cmp::Reverse(classes[p] == RegionClass::Large));
        let mut chosen: std::collections::HashMap<usize, usize> = Default::default();
        let mut ok = true;
        for p in order {
            let pos = neigh.iter().position(|&t| class_ok(t, classes[p]));
            match pos {
                Some(k) => {
                    chosen.insert(p, neigh.remove(k));
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        // build assignments in stage order
        let mut assignments = Vec::with_capacity(stages.len());
        for (i, s) in stages.iter().enumerate() {
            let tile = if i == sel {
                hub
            } else if let Some(&t) = chosen.get(&i) {
                t
            } else {
                return Err(Error::Placement(
                    "diamond placement only supports pred/then/else/select stages".into(),
                ));
            };
            assignments.push(Assignment {
                op: s.op,
                tile,
                class: fabric.tiles[tile].class,
                tail: None,
            });
        }
        return Ok(Placement { assignments });
    }
    Err(Error::Placement(
        "no hub tile with enough free class-compatible neighbours for the branch diamond".into(),
    ))
}

/// Route every `Source::Stage` edge of the pipeline.
fn route_stages(fabric: &Fabric, stages: &[Stage], placement: &Placement) -> Result<Vec<Route>> {
    // tiles that consume (host operators) block pass-through routing
    let mut blocked = vec![false; fabric.tiles.len()];
    for a in &placement.assignments {
        blocked[a.tile] = true;
    }
    // previously-occupied tiles block too
    for (t, tile) in fabric.tiles.iter().enumerate() {
        if tile.resident.is_some() {
            blocked[t] = true;
        }
    }

    let mut routes = Vec::new();
    for (i, s) in stages.iter().enumerate() {
        for src in &s.sources {
            if let Source::Stage { index, .. } = src {
                let from = placement.tile_of(*index).ok_or_else(|| {
                    Error::Placement(format!("stage {index} missing from placement"))
                })?;
                let to = placement
                    .tile_of(i)
                    .ok_or_else(|| Error::Placement(format!("stage {i} missing")))?;
                routes.push(shortest_route(&fabric.mesh, from, to, &blocked)?);
            }
        }
    }
    Ok(routes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    fn setup() -> (Fabric, BitstreamLibrary) {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        (Fabric::new(cfg).unwrap(), lib)
    }

    #[test]
    fn vmul_reduce_compiles_contiguous() {
        let (f, lib) = setup();
        let acc = Jit.compile(&f, &lib, &Composition::vmul_reduce(4096)).unwrap();
        assert_eq!(acc.stages().len(), 2);
        assert_eq!(acc.total_hops(), 0, "dynamic overlay must be contiguous");
        assert!(acc.placement().is_injective());
        assert!(acc.program().len() > 5);
    }

    #[test]
    fn chain_compiles() {
        let (f, lib) = setup();
        let comp =
            Composition::chain(&[OperatorKind::Abs, OperatorKind::Sqrt, OperatorKind::Log], 1024)
                .unwrap();
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        assert_eq!(acc.stages().len(), 3);
        // sqrt & log need the two large tiles; abs can sit anywhere —
        // at most one skipped tile between stages.
        assert!(acc.total_hops() <= 2, "hops: {}", acc.total_hops());
    }

    #[test]
    fn branch_places_as_diamond() {
        let (f, lib) = setup();
        let comp = Composition::branch(0.0, OperatorKind::Relu, OperatorKind::Neg, 512);
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        assert_eq!(acc.stages().len(), 4);
        // all three producers adjacent to the select hub
        assert_eq!(acc.total_hops(), 0);
        let sel_tile = acc.placement().assignments[3].tile;
        for a in &acc.placement().assignments[..3] {
            assert_eq!(f.mesh.manhattan(a.tile, sel_tile), 1);
        }
    }

    #[test]
    fn branch_with_large_arms_places() {
        let (f, lib) = setup();
        let comp = Composition::branch(0.5, OperatorKind::Sqrt, OperatorKind::Square, 256);
        let acc = Jit.compile(&f, &lib, &comp).unwrap();
        let sqrt_stage = acc
            .placement()
            .assignments
            .iter()
            .find(|a| a.op == OperatorKind::Sqrt)
            .unwrap();
        assert_eq!(sqrt_stage.class, RegionClass::Large);
    }

    #[test]
    fn occupied_fabric_reduces_capacity() {
        let (mut f, lib) = setup();
        // occupy 8 of 9 tiles
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        let bl = lib.get(OperatorKind::Add, RegionClass::Large).unwrap().clone();
        for t in 0..8 {
            let b = if f.cfg.is_large_tile(t) { &bl } else { &bs };
            f.load_bitstream(t, b).unwrap();
        }
        let err = Jit.compile(&f, &lib, &Composition::vmul_reduce(64)).unwrap_err();
        assert!(err.is_capacity());
    }

    #[test]
    fn scalar_channels_surface_in_accelerator() {
        let (f, lib) = setup();
        let acc = Jit.compile(&f, &lib, &Composition::filter_reduce(0.75, 512)).unwrap();
        assert_eq!(acc.scalar_channels(), &[0.75]);
    }

    #[test]
    fn fusion_collapses_vmul_reduce_to_one_tile() {
        let (f, lib) = setup();
        let comp = Composition::vmul_reduce(1024);
        let acc = Jit.compile_with(&f, &lib, &comp, true).unwrap();
        assert_eq!(acc.stages().len(), 1);
        assert_eq!(acc.spec.fused_pairs, 1);
        let s = &acc.stages()[0];
        assert_eq!(s.op, OperatorKind::Mul);
        assert_eq!(s.fused, Some(OperatorKind::AccSum));
        assert!(s.is_reduce);
        // mul+acc_sum = (5, 270, 340): over the Small budget, fits Large
        assert_eq!(acc.spec.classes, vec![RegionClass::Large]);
        let a = &acc.placement().assignments[0];
        assert_eq!(a.tail, Some(OperatorKind::AccSum));
        assert_eq!(a.class, RegionClass::Large);
        assert_eq!(acc.total_hops(), 0);
    }

    #[test]
    fn fusion_pairs_up_a_map_chain() {
        let (f, lib) = setup();
        let ops = [
            OperatorKind::Neg,
            OperatorKind::Abs,
            OperatorKind::Square,
            OperatorKind::Relu,
            OperatorKind::Neg,
        ];
        let comp = Composition::chain(&ops, 1024).unwrap();
        let spec = Jit.frontend_with(&lib, &comp, true).unwrap();
        // pair-only scan: (neg+abs)(square+relu)(neg) — 5 tiles become 3
        assert_eq!(spec.stages.len(), 3);
        assert_eq!(spec.fused_pairs, 2);
        assert_eq!(spec.stages[0].fused, Some(OperatorKind::Abs));
        assert_eq!(spec.stages[1].fused, Some(OperatorKind::Relu));
        assert_eq!(spec.stages[2].fused, None);
        // neg+abs = (0,60,80) fits Small; square+relu = (3,200,240) needs Large
        assert_eq!(
            spec.classes,
            vec![RegionClass::Small, RegionClass::Large, RegionClass::Small]
        );
        // sources were remapped over the removed indices
        assert_eq!(spec.stages[1].sources, vec![Source::Stage { index: 0, slot: 0 }]);
        assert_eq!(spec.stages[2].sources, vec![Source::Stage { index: 1, slot: 0 }]);
        // and the whole thing still places and routes
        let plan = Jit.place_onto(&f, &spec).unwrap();
        assert_eq!(plan.placement.assignments.len(), 3);
        assert_eq!(plan.placement.assignments[1].tail, Some(OperatorKind::Relu));
    }

    #[test]
    fn fusion_skips_pairs_that_fit_no_region() {
        let (_, lib) = setup();
        // sin+exp = (15, 1830, 2280): over even the Large budget — no fuse
        let comp =
            Composition::chain(&[OperatorKind::Sin, OperatorKind::Exp], 1024).unwrap();
        let spec = Jit.frontend_with(&lib, &comp, true).unwrap();
        assert_eq!(spec.stages.len(), 2);
        assert_eq!(spec.fused_pairs, 0);
    }

    #[test]
    fn fused_and_unfused_keys_differ() {
        let (_, lib) = setup();
        let comp = Composition::vmul_reduce(1024);
        let unfused = Jit.frontend(&lib, &comp).unwrap();
        let fused = Jit.frontend_with(&lib, &comp, true).unwrap();
        assert_eq!(unfused.key, comp.cache_key());
        assert_eq!(fused.key, comp.cache_key() ^ FUSED_KEY_SALT);
        assert_ne!(unfused.key, fused.key);
        // fusion-on with nothing fusible still salts: the policy, not the
        // outcome, decides the cache namespace (lookups must predict keys
        // without running the pass)
        let single = Composition::map(OperatorKind::Sqrt, 512);
        let spec = Jit.frontend_with(&lib, &single, true).unwrap();
        assert_eq!(spec.fused_pairs, 0);
        assert_eq!(spec.key, single.cache_key() ^ FUSED_KEY_SALT);
    }

    /// The split itself: the front end is fabric-blind, and placement-only
    /// recompiles land on whatever tiles the target fabric has free.
    #[test]
    fn place_onto_respects_target_occupancy() {
        let (f_empty, lib) = setup();
        let comp = Composition::vmul_reduce(256);
        let spec = Arc::new(Jit.frontend(&lib, &comp).unwrap());
        assert_eq!(spec.key, comp.cache_key());
        assert_eq!(spec.stages.len(), spec.classes.len());
        assert!(spec.classes.iter().all(|c| *c == RegionClass::Small));

        let plan_a = Jit.place_onto(&f_empty, &spec).unwrap();
        assert_eq!(plan_a.fabric, f_empty.id);

        // a second fabric whose first snake tile is occupied
        let (mut f_busy, _) = setup();
        let bs = lib.get(OperatorKind::Abs, RegionClass::Small).unwrap().clone();
        f_busy.load_bitstream(0, &bs).unwrap();
        let plan_b = Jit.place_onto(&f_busy, &spec).unwrap();
        assert_eq!(plan_b.fabric, f_busy.id);
        assert_ne!(plan_a.fabric, plan_b.fabric);
        assert!(
            plan_b.placement.assignments.iter().all(|a| a.tile != 0),
            "respecialized placement must avoid the occupied tile: {:?}",
            plan_b.placement.assignments
        );
        // both plans realize the same program shape (placement-only phase)
        assert_eq!(plan_a.chunk, plan_b.chunk);
        assert_eq!(plan_a.scalar_channels, plan_b.scalar_channels);
    }

    /// The compactor's republish path: a remapped placement re-routes and
    /// re-codegens without consulting the placer (which would refuse the
    /// now-occupied tiles).
    #[test]
    fn plan_for_placement_respects_the_given_tiles() {
        let (f, lib) = setup();
        let comp = Composition::vmul_reduce(256);
        let spec = Jit.frontend(&lib, &comp).unwrap();
        let plan = Jit.place_onto(&f, &spec).unwrap();
        // remap both stages to a different adjacent pair
        let mut placement = plan.placement.clone();
        placement.assignments[0].tile = 4;
        placement.assignments[1].tile = 5;
        let replanned = Jit.plan_for_placement(&f, &spec, placement).unwrap();
        assert_eq!(replanned.placement.assignments[0].tile, 4);
        assert_eq!(replanned.placement.assignments[1].tile, 5);
        assert_eq!(replanned.chunk, plan.chunk);
        assert_eq!(replanned.total_hops(), 0);
        // stage-count mismatch is refused outright
        let short = Placement { assignments: plan.placement.assignments[..1].to_vec() };
        assert!(Jit.plan_for_placement(&f, &spec, short).is_err());
    }
}
