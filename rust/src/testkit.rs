//! Deterministic test harness for the service layer: a virtual clock and a
//! scripted-latency engine shim.
//!
//! Wall-clock-sleep tests cannot pin down ordering, fairness or starvation
//! properties — they only sample one scheduling of many. This module makes
//! the whole front-end pipeline single-threaded and virtual-timed instead:
//!
//! * [`VirtualClock`] — a monotonic `u64` tick counter. Nothing sleeps;
//!   time moves only when the harness advances it.
//! * [`ScriptedEngine`] — a [`Dispatch`] backend standing in for the
//!   worker pool. Dispatches are *scheduled* at `now + latency(i, req)`
//!   (the scripted latency decides completion **order**), and served on an
//!   embedded single-fabric [`Coordinator`] when their due time is reached
//!   — so replies carry real computed values tests can fingerprint, while
//!   completion order is an exact function of the script. A bounded
//!   `capacity` models a saturated pool: excess dispatches are rejected
//!   with [`Rejected::Busy`], exercising the reactor's retry path
//!   deterministically.
//! * [`drive`] — the canonical loop: alternate one reactor poll with one
//!   engine advance until the front end is quiescent, panicking after
//!   `max_steps` (the liveness bound — a starved session shows up as a
//!   panic here, not as a hang).
//!
//! The module is compiled unconditionally (no `cfg(test)`) so integration
//! tests, benches and downstream harnesses can use it; it is never on the
//! request path.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::OverlayConfig;
use crate::coordinator::frontend::{Dispatch, Reactor, Rejected};
use crate::coordinator::pool::{Completion, CompletionQueue, Ticket};
use crate::coordinator::{Coordinator, Request};
use crate::error::{Error, Result};
use crate::exec::Value;

/// Canonical bit-level fingerprint of a computed [`Value`]: every `f32` as
/// its raw bit pattern, in order. Two runs are bit-identical iff their
/// fingerprints are equal — `==` on the floats themselves would also
/// accept `-0.0` for `0.0`, which is too weak for "transient faults must
/// not perturb the result by even one ulp" assertions (the chaos soak).
pub fn fingerprint(v: &Value) -> Vec<u32> {
    match v {
        Value::Scalar(x) => vec![x.to_bits()],
        Value::Vector(xs) => xs.iter().map(|x| x.to_bits()).collect(),
    }
}

/// A monotonic virtual clock: ticks advance only when told to.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: u64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0 }
    }

    /// The current virtual time.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Advance to `t` (monotonic: never moves backwards).
    pub fn advance_to(&mut self, t: u64) {
        self.now = self.now.max(t);
    }
}

/// The latency script: virtual ticks between dispatch and completion, as a
/// function of the dispatch index (0, 1, 2, …) and the request.
pub type LatencyFn = Box<dyn FnMut(u64, &Request) -> u64 + Send>;

/// One scheduled (dispatched, not yet completed) request.
struct Scheduled {
    ticket: Ticket,
    request: Request,
    completions: Arc<CompletionQueue>,
}

struct EngineInner {
    coord: Coordinator,
    clock: VirtualClock,
    /// Pending completions keyed by `(due tick, dispatch order)` — ties in
    /// due time complete in dispatch order, deterministically.
    pending: BTreeMap<(u64, u64), Scheduled>,
    order: u64,
    dispatched: u64,
    capacity: usize,
    latency: LatencyFn,
    high_water: usize,
}

/// A deterministic [`Dispatch`] backend with scripted completion latency.
pub struct ScriptedEngine {
    inner: Mutex<EngineInner>,
}

impl ScriptedEngine {
    /// Build an engine over one fabric. `capacity` bounds concurrently
    /// scheduled requests (beyond it, dispatch answers [`Rejected::Busy`]);
    /// `latency` maps `(dispatch index, request)` to virtual ticks.
    pub fn new(
        cfg: OverlayConfig,
        capacity: usize,
        latency: impl FnMut(u64, &Request) -> u64 + Send + 'static,
    ) -> Result<ScriptedEngine> {
        if capacity == 0 {
            return Err(Error::Config("scripted engine needs capacity for one request".into()));
        }
        Ok(ScriptedEngine {
            inner: Mutex::new(EngineInner {
                coord: Coordinator::new(cfg)?,
                clock: VirtualClock::new(),
                pending: BTreeMap::new(),
                order: 0,
                dispatched: 0,
                capacity,
                latency: Box::new(latency),
                high_water: 0,
            }),
        })
    }

    /// [`ScriptedEngine::new`] with a constant latency.
    pub fn constant(cfg: OverlayConfig, capacity: usize, ticks: u64) -> Result<ScriptedEngine> {
        Self::new(cfg, capacity, move |_, _| ticks)
    }

    fn lock(&self) -> MutexGuard<'_, EngineInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Current virtual time.
    pub fn now(&self) -> u64 {
        self.lock().clock.now()
    }

    /// Requests scheduled but not yet completed.
    pub fn in_service(&self) -> usize {
        self.lock().pending.len()
    }

    /// High-water mark of concurrently scheduled requests — what the
    /// admission caps are supposed to bound.
    pub fn high_water(&self) -> usize {
        self.lock().high_water
    }

    /// Total dispatches accepted so far.
    pub fn dispatched(&self) -> u64 {
        self.lock().dispatched
    }

    /// Advance the clock to the next due completion, serve that request on
    /// the embedded coordinator, and push its [`Completion`]. Returns
    /// `false` when nothing is in service.
    pub fn advance_next(&self) -> bool {
        let mut g = self.lock();
        let Some((&key, _)) = g.pending.iter().next() else {
            return false;
        };
        let s = g.pending.remove(&key).expect("key just observed");
        g.clock.advance_to(key.0);
        let result = g.coord.submit(&s.request);
        s.completions.push(Completion { ticket: s.ticket, result });
        true
    }
}

impl Dispatch for ScriptedEngine {
    fn submit_async(
        &self,
        request: Request,
        completions: &Arc<CompletionQueue>,
    ) -> std::result::Result<Ticket, Rejected> {
        let mut g = self.lock();
        if g.pending.len() >= g.capacity {
            return Err(Rejected::Busy(request));
        }
        let idx = g.dispatched;
        let now = g.clock.now();
        let ticks = (g.latency)(idx, &request);
        let due = now + ticks;
        g.dispatched += 1;
        let order = g.order;
        g.order += 1;
        let ticket = completions.next_ticket();
        g.pending.insert(
            (due, order),
            Scheduled { ticket, request, completions: completions.clone() },
        );
        let depth = g.pending.len();
        g.high_water = g.high_water.max(depth);
        Ok(ticket)
    }
}

/// Drive a reactor against a scripted engine to quiescence: one poll, one
/// completion, repeat. Returns the number of polls. Panics after
/// `max_steps` polls — the deterministic stand-in for "this would have
/// hung": starvation, a lost reply, or an admission livelock all trip it.
pub fn drive<B: Dispatch>(
    reactor: &Reactor<B>,
    engine: &ScriptedEngine,
    max_steps: usize,
) -> usize {
    let mut polls = 0;
    loop {
        let stats = reactor.poll_once();
        polls += 1;
        assert!(
            polls <= max_steps,
            "front end failed to quiesce within {max_steps} polls \
             (queued={} inflight={} in_service={})",
            stats.queued,
            stats.inflight,
            engine.in_service()
        );
        if engine.advance_next() {
            continue;
        }
        if stats.idle() {
            return polls;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::Composition;
    use crate::workload;

    fn req(n: usize, seed: u64) -> Request {
        Request::dynamic(
            Composition::vmul_reduce(n),
            vec![workload::vector(n, seed, 0.1, 1.0), workload::vector(n, seed + 1, 0.1, 1.0)],
        )
    }

    #[test]
    fn fingerprint_distinguishes_signed_zero() {
        assert_eq!(fingerprint(&Value::Scalar(1.5)), fingerprint(&Value::Scalar(1.5)));
        assert_ne!(fingerprint(&Value::Scalar(0.0)), fingerprint(&Value::Scalar(-0.0)));
        let v = Value::Vector(vec![1.0, 2.0]);
        assert_eq!(fingerprint(&v), vec![1.0f32.to_bits(), 2.0f32.to_bits()]);
    }

    #[test]
    fn virtual_clock_is_monotonic() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(5);
        c.advance_to(3);
        assert_eq!(c.now(), 5);
    }

    #[test]
    fn scripted_engine_completes_in_due_order_with_real_values() {
        // reversed latencies: the second dispatch completes first
        let engine = ScriptedEngine::new(OverlayConfig::default(), 8, |i, _| 10 - i).unwrap();
        let cq = Arc::new(CompletionQueue::new());
        let t0 = engine.submit_async(req(64, 1), &cq).unwrap();
        let t1 = engine.submit_async(req(64, 2), &cq).unwrap();
        assert_eq!(engine.in_service(), 2);
        assert!(engine.advance_next());
        assert!(engine.advance_next());
        assert!(!engine.advance_next());
        assert_eq!(engine.now(), 10, "clock lands on the last due tick");
        let done = cq.drain();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].ticket, t1, "shorter latency completes first");
        assert_eq!(done[1].ticket, t0);
        for c in done {
            c.result.expect("served for real");
        }
    }

    #[test]
    fn scripted_engine_rejects_beyond_capacity() {
        let engine = ScriptedEngine::constant(OverlayConfig::default(), 1, 5).unwrap();
        let cq = Arc::new(CompletionQueue::new());
        engine.submit_async(req(64, 1), &cq).unwrap();
        match engine.submit_async(req(64, 2), &cq) {
            Err(Rejected::Busy(r)) => assert_eq!(r.inputs.len(), 2, "request handed back"),
            other => panic!("expected Busy, got {:?}", other.map(|_| ())),
        }
        assert_eq!(engine.high_water(), 1);
        assert!(engine.advance_next());
        engine.submit_async(req(64, 2), &cq).unwrap();
        assert_eq!(engine.dispatched(), 2);
    }

    #[test]
    fn zero_capacity_rejected() {
        assert!(ScriptedEngine::constant(OverlayConfig::default(), 0, 1).is_err());
    }
}
