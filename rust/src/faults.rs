//! Deterministic fault-injection plane for the self-healing machinery.
//!
//! Real PR downloads fail transiently, fabric regions die, and worker
//! threads panic; the serving tier recovers from all three (retry,
//! quarantine + re-place, supervise + replay). This module makes those
//! failures *injectable and reproducible* so the recovery ladder is proven
//! by tests instead of waited for in production.
//!
//! A [`FaultSpec`] is a declarative schedule: explicit 1-based ordinals per
//! injection site ("the 3rd download fails transiently", "the worker
//! panics on its 1st burst") plus an optional seeded per-mille rate for
//! transient download faults. Every decision is a pure function of
//! `(seed, site, ordinal)` — no wall clock, no global RNG — so the same
//! spec replays the same fault sequence on every run and every platform
//! (the same discipline as [`crate::workload`]'s seeded streams).
//!
//! The runtime half is [`FaultPlane`]: [`FaultPlane::NoFaults`] is the
//! default and costs one enum discriminant check per site — no atomics, no
//! allocation — so the hot path is unaffected unless faults are explicitly
//! enabled ([`FaultPlane::from_spec`] with a non-empty spec). Sites:
//!
//! * **PR download** ([`crate::reconfig::PrManager::apply_with`]) —
//!   [`DownloadFault::Transient`] aborts one ICAP transfer (the retry
//!   budget in [`crate::config::ServiceConfig::download_retries`] decides
//!   how many re-arms are attempted before giving up);
//!   [`DownloadFault::Permanent`] kills the region: the tile is
//!   quarantined and the placer routes around it from then on.
//! * **tile execution** ([`crate::exec::Engine::run`]) —
//!   [`ExecFault::WrongBits`] models a corrupted configuration (the region
//!   is cleared and re-downloaded clean); [`ExecFault::RegionDead`] models
//!   a hard region fault (quarantine + re-place elsewhere).
//! * **worker panic** ([`crate::coordinator::pool::WorkerPool`]) — the
//!   serving thread panics at a scheduled burst ordinal; supervision
//!   catches it, replays the burst, and respawns the serving state.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What an injected PR-download fault does to the transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownloadFault {
    /// The transfer aborts but the region is healthy: retry it.
    Transient,
    /// The region fails to configure at all: quarantine the tile.
    Permanent,
}

/// What an injected execution fault does to the serving tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecFault {
    /// The region holds corrupted configuration bits: its output cannot be
    /// trusted, but a clean re-download fixes it.
    WrongBits,
    /// The region died under load: quarantine the tile and re-place.
    RegionDead,
}

/// Declarative, deterministic fault schedule (see the module docs).
///
/// All ordinal lists are **1-based** per injection site: the first PR
/// download anywhere on the fabric is download ordinal 1, the first
/// executed accelerator run is exec ordinal 1, the first served burst is
/// burst ordinal 1. Retries consume ordinals too — a transient fault at
/// download 3 makes the retry download 4 — so a schedule spacing its
/// ordinals apart injects exactly one fault per recovery.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    /// Seed for the rate-based decisions (ignored when every rate is 0).
    pub seed: u64,
    /// Per-mille probability that any given PR download faults
    /// transiently (0 = never, 1000 = always), decided per ordinal from
    /// `seed` — deterministic across runs.
    pub transient_download_permille: u32,
    /// Explicit download ordinals that fault transiently.
    pub transient_downloads: Vec<u64>,
    /// Explicit download ordinals that fault permanently (region dead).
    pub permanent_downloads: Vec<u64>,
    /// Exec ordinals whose serving tile holds wrong configuration bits.
    pub wrong_bits: Vec<u64>,
    /// Exec ordinals whose serving tile dies (permanent).
    pub region_dead: Vec<u64>,
    /// Burst ordinals at which the serving worker thread panics.
    pub worker_panics: Vec<u64>,
}

impl FaultSpec {
    /// True when this spec injects nothing — the zero-cost default.
    pub fn is_off(&self) -> bool {
        self.transient_download_permille == 0
            && self.transient_downloads.is_empty()
            && self.permanent_downloads.is_empty()
            && self.wrong_bits.is_empty()
            && self.region_dead.is_empty()
            && self.worker_panics.is_empty()
    }

    /// Rate-based transient download faults only (`--faults
    /// transient-downloads`): every recovery is a pure retry, so outputs
    /// must stay bit-identical to a fault-free run.
    pub fn transient(seed: u64, permille: u32) -> FaultSpec {
        FaultSpec { seed, transient_download_permille: permille, ..FaultSpec::default() }
    }

    /// The chaos preset (`--faults chaos`): rate-based transient downloads
    /// plus one permanent region fault and one worker panic early in the
    /// run — every recovery rung fires at least once.
    pub fn chaos(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            transient_download_permille: 100,
            region_dead: vec![2],
            worker_panics: vec![1],
            ..FaultSpec::default()
        }
    }
}

/// The runtime fault plane, shared by every engine and worker of a service
/// ([`Arc`]-cloned so all sites draw ordinals from one schedule).
#[derive(Debug)]
pub enum FaultPlane {
    /// No injection: every site check is a single discriminant test.
    NoFaults,
    /// Seeded, schedule-driven injection.
    Seeded(SeededFaults),
}

/// Per-site ordinal counters plus the spec they are judged against.
#[derive(Debug)]
pub struct SeededFaults {
    spec: FaultSpec,
    downloads: AtomicU64,
    execs: AtomicU64,
    bursts: AtomicU64,
}

/// splitmix64 finalizer: the per-ordinal decision hash (same family as
/// [`crate::workload::Rng`]'s seeding, re-derived here so the fault plane
/// stays self-contained).
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultPlane {
    /// The shared zero-cost default.
    pub fn none() -> Arc<FaultPlane> {
        Arc::new(FaultPlane::NoFaults)
    }

    /// Build the plane for `spec`; an all-off spec collapses to
    /// [`FaultPlane::NoFaults`] so "configured but empty" costs nothing.
    pub fn from_spec(spec: FaultSpec) -> Arc<FaultPlane> {
        if spec.is_off() {
            FaultPlane::none()
        } else {
            Arc::new(FaultPlane::Seeded(SeededFaults {
                spec,
                downloads: AtomicU64::new(0),
                execs: AtomicU64::new(0),
                bursts: AtomicU64::new(0),
            }))
        }
    }

    /// True when nothing will ever be injected.
    pub fn is_off(&self) -> bool {
        matches!(self, FaultPlane::NoFaults)
    }

    /// Consult the schedule for the next PR download (consumes one
    /// download ordinal when seeded).
    pub fn next_download(&self) -> Option<DownloadFault> {
        let FaultPlane::Seeded(s) = self else {
            return None;
        };
        let ord = s.downloads.fetch_add(1, Ordering::Relaxed) + 1;
        if s.spec.permanent_downloads.contains(&ord) {
            return Some(DownloadFault::Permanent);
        }
        if s.spec.transient_downloads.contains(&ord) {
            return Some(DownloadFault::Transient);
        }
        let permille = u64::from(s.spec.transient_download_permille);
        let draw = mix(s.spec.seed ^ ord.wrapping_mul(0x9e37_79b9_7f4a_7c15)) % 1000;
        if permille > 0 && draw < permille {
            return Some(DownloadFault::Transient);
        }
        None
    }

    /// Consult the schedule for the next accelerator execution (consumes
    /// one exec ordinal when seeded).
    pub fn next_exec(&self) -> Option<ExecFault> {
        let FaultPlane::Seeded(s) = self else {
            return None;
        };
        let ord = s.execs.fetch_add(1, Ordering::Relaxed) + 1;
        if s.spec.region_dead.contains(&ord) {
            return Some(ExecFault::RegionDead);
        }
        if s.spec.wrong_bits.contains(&ord) {
            return Some(ExecFault::WrongBits);
        }
        None
    }

    /// Panic if the next burst ordinal is scheduled to crash the worker.
    /// Callers invoke this *before* committing to serve a burst, so the
    /// supervisor can tell an injected crash (burst still intact: replay
    /// it) from a mid-serve one (reply sinks already fail-safed).
    pub fn maybe_worker_panic(&self) {
        let FaultPlane::Seeded(s) = self else {
            return;
        };
        let ord = s.bursts.fetch_add(1, Ordering::Relaxed) + 1;
        if s.spec.worker_panics.contains(&ord) {
            panic!("injected fault: worker panic at burst {ord}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_off_and_collapses_to_no_faults() {
        let spec = FaultSpec::default();
        assert!(spec.is_off());
        let plane = FaultPlane::from_spec(spec);
        assert!(plane.is_off());
        for _ in 0..100 {
            assert_eq!(plane.next_download(), None);
            assert_eq!(plane.next_exec(), None);
            plane.maybe_worker_panic(); // must never fire
        }
    }

    #[test]
    fn explicit_ordinals_fire_exactly_once_each() {
        let spec = FaultSpec {
            transient_downloads: vec![2],
            permanent_downloads: vec![4],
            wrong_bits: vec![1],
            region_dead: vec![3],
            ..FaultSpec::default()
        };
        let plane = FaultPlane::from_spec(spec);
        assert!(!plane.is_off());
        let downloads: Vec<_> = (0..5).map(|_| plane.next_download()).collect();
        assert_eq!(
            downloads,
            vec![
                None,
                Some(DownloadFault::Transient),
                None,
                Some(DownloadFault::Permanent),
                None
            ]
        );
        let execs: Vec<_> = (0..4).map(|_| plane.next_exec()).collect();
        assert_eq!(
            execs,
            vec![Some(ExecFault::WrongBits), None, Some(ExecFault::RegionDead), None]
        );
    }

    #[test]
    fn rate_decisions_are_deterministic_and_roughly_calibrated() {
        let draw = |seed: u64| -> Vec<bool> {
            let plane = FaultPlane::from_spec(FaultSpec::transient(seed, 200));
            (0..1000).map(|_| plane.next_download().is_some()).collect()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same seed must replay the same schedule");
        assert_ne!(a, draw(8), "different seeds must differ");
        let hits = a.iter().filter(|&&h| h).count();
        assert!((120..280).contains(&hits), "200‰ drew {hits}/1000");
    }

    #[test]
    fn injected_worker_panic_fires_at_its_ordinal() {
        let plane =
            FaultPlane::from_spec(FaultSpec { worker_panics: vec![2], ..FaultSpec::default() });
        plane.maybe_worker_panic(); // burst 1: fine
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plane.maybe_worker_panic() // burst 2: scheduled crash
        }));
        assert!(r.is_err(), "burst 2 must panic");
        plane.maybe_worker_panic(); // burst 3: fine again
    }

    #[test]
    fn chaos_preset_covers_every_rung() {
        let spec = FaultSpec::chaos(1);
        assert!(!spec.is_off());
        assert!(spec.transient_download_permille > 0);
        assert!(!spec.region_dead.is_empty());
        assert!(!spec.worker_panics.is_empty());
    }
}
