//! Next-composition prediction for speculative reconfiguration.
//!
//! The dynamic overlay's only penalty is PR time (Fig. 3), and the paper
//! amortizes it reactively: the download is paid on the critical path of
//! the first request that needs a different accelerator. This module moves
//! that download *off* the critical path for predictable request streams:
//! a per-worker first-order Markov chain over recent accelerator-cache
//! keys learns "after composition A, composition B usually follows", and
//! the coordinator prefetches B's bitstreams into idle healthy tiles
//! during quiet drain windows (see `Coordinator::maintain`).
//!
//! The predictor is deliberately boring: no clocks, no randomness, bounded
//! memory, and a confidence gate so it stays silent until a transition has
//! actually repeated. Determinism matters — the service's tests replay
//! seeded request streams and expect bit-identical metrics.

use std::collections::HashMap;

/// Default minimum observations of a `(from, to)` transition before it may
/// be predicted.
pub const MIN_SAMPLES: u32 = 2;

/// Default confidence gate: the winning successor must account for more
/// than this fraction of all transitions out of the current key.
pub const CONFIDENCE: f64 = 0.5;

/// Bound on distinct "from" keys tracked (and on successors per key).
/// Beyond it, the coldest entry is dropped — the table is a working-set
/// sketch, not a history.
pub const TABLE_CAP: usize = 64;

/// First-order Markov predictor over accelerator-cache keys.
#[derive(Debug, Clone)]
pub struct NextPredictor {
    /// `table[from][to]` = times `to` followed `from`.
    table: HashMap<u64, HashMap<u64, u32>>,
    /// The most recently observed key (the chain's current state).
    last: Option<u64>,
    min_samples: u32,
    confidence: f64,
    cap: usize,
}

impl Default for NextPredictor {
    fn default() -> Self {
        Self::new(MIN_SAMPLES, CONFIDENCE)
    }
}

impl NextPredictor {
    /// A predictor with explicit gates (see [`MIN_SAMPLES`], [`CONFIDENCE`]).
    pub fn new(min_samples: u32, confidence: f64) -> Self {
        Self {
            table: HashMap::new(),
            last: None,
            min_samples: min_samples.max(1),
            confidence,
            cap: TABLE_CAP,
        }
    }

    /// Record that `key` was just requested, extending the chain from the
    /// previously observed key.
    pub fn observe(&mut self, key: u64) {
        if let Some(prev) = self.last {
            if !self.table.contains_key(&prev) && self.table.len() >= self.cap {
                self.evict_coldest();
            }
            let succ = self.table.entry(prev).or_default();
            if !succ.contains_key(&key) && succ.len() >= self.cap {
                // successor fan-out is saturated: this key is effectively
                // unpredictable; drop the new edge rather than churn
            } else {
                *succ.entry(key).or_insert(0) += 1;
            }
        }
        self.last = Some(key);
    }

    /// The predicted next key, if the chain's current state has a successor
    /// that clears both the sample and confidence gates. Ties break on the
    /// smaller key so prediction is deterministic across `HashMap` orders.
    pub fn predict(&self) -> Option<u64> {
        let succ = self.table.get(&self.last?)?;
        let total: u32 = succ.values().sum();
        if total == 0 {
            return None;
        }
        let (&best, &count) = succ
            .iter()
            .max_by(|(ka, va), (kb, vb)| va.cmp(vb).then(kb.cmp(ka)))?;
        if count < self.min_samples {
            return None;
        }
        if (count as f64) <= self.confidence * total as f64 {
            return None;
        }
        Some(best)
    }

    /// Break the observation chain: the next [`NextPredictor::observe`]
    /// starts a new run instead of recording an edge from the previous
    /// key. Called at stream discontinuities — a stolen composition
    /// group arriving on a worker, a supervised-restart replay — where
    /// neighboring keys are adjacent in time but not in any client's
    /// request order, so learning the edge would dilute the real
    /// successors' confidence below the prediction gate. The learned
    /// table is untouched.
    pub fn break_chain(&mut self) {
        self.last = None;
    }

    /// Distinct chain states currently tracked.
    pub fn states(&self) -> usize {
        self.table.len()
    }

    /// Drop the "from" key with the fewest total observations (ties break
    /// on the smaller key — deterministic).
    fn evict_coldest(&mut self) {
        let coldest = self
            .table
            .iter()
            .map(|(&k, succ)| (succ.values().sum::<u32>(), k))
            .min_by(|(ca, ka), (cb, kb)| ca.cmp(cb).then(ka.cmp(kb)))
            .map(|(_, k)| k);
        if let Some(k) = coldest {
            self.table.remove(&k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_predictor_is_silent() {
        let p = NextPredictor::default();
        assert_eq!(p.predict(), None);
    }

    #[test]
    fn single_observation_is_not_enough() {
        let mut p = NextPredictor::default();
        p.observe(1);
        p.observe(2);
        p.observe(1);
        // 1 -> 2 seen once: below the sample gate
        assert_eq!(p.predict(), None);
    }

    #[test]
    fn repeated_transition_is_predicted() {
        let mut p = NextPredictor::default();
        for _ in 0..3 {
            p.observe(1);
            p.observe(2);
        }
        p.observe(1);
        assert_eq!(p.predict(), Some(2));
    }

    #[test]
    fn cyclic_stream_predicts_each_next_key() {
        let mut p = NextPredictor::default();
        let cycle = [10u64, 20, 30, 40];
        for _ in 0..3 {
            for &k in &cycle {
                p.observe(k);
            }
        }
        for (i, &k) in cycle.iter().enumerate() {
            p.observe(k);
            assert_eq!(p.predict(), Some(cycle[(i + 1) % cycle.len()]), "after {k}");
        }
    }

    #[test]
    fn low_confidence_stays_silent() {
        let mut p = NextPredictor::default();
        // after 1, successors 2 and 3 are equally likely: 50% each does
        // not clear the strict >50% gate
        for _ in 0..4 {
            p.observe(1);
            p.observe(2);
            p.observe(1);
            p.observe(3);
        }
        p.observe(1);
        assert_eq!(p.predict(), None);
    }

    #[test]
    fn dominant_successor_wins_despite_noise() {
        let mut p = NextPredictor::default();
        for _ in 0..8 {
            p.observe(1);
            p.observe(2);
        }
        p.observe(1);
        p.observe(3);
        p.observe(1);
        assert_eq!(p.predict(), Some(2));
    }

    #[test]
    fn table_is_bounded() {
        let mut p = NextPredictor::default();
        for k in 0..(TABLE_CAP as u64 * 4) {
            p.observe(k);
        }
        assert!(p.states() <= TABLE_CAP);
    }

    #[test]
    fn break_chain_cuts_false_edges_but_keeps_the_table() {
        // low gates so a single false edge would flip an outcome below
        let mut p = NextPredictor::new(1, 0.5);
        p.observe(1);
        p.observe(2);
        p.observe(1);
        p.observe(2);
        // chain ends at 2; a steal boundary delivers key 9 adjacent in
        // time only — break, then observe the stolen key
        p.break_chain();
        p.observe(9);
        assert_eq!(p.predict(), None, "fresh chain state has no successors");
        // the learned 1→2 edge survived the break
        p.observe(1);
        assert_eq!(p.predict(), Some(2));
        // and state 2 still predicts its real successor: had the
        // boundary edge 2→9 been learned, 2's successors would tie
        // 50/50 and the strict >50% confidence gate would go silent
        p.observe(2);
        assert_eq!(p.predict(), Some(1), "the 2→9 boundary edge must not exist");
    }

    #[test]
    fn prediction_is_deterministic_on_ties() {
        // equal counts: the smaller key must win every time (and then be
        // suppressed by the confidence gate — but the tie-break itself is
        // what this pins, via a 3-way split where one key dominates)
        let mut build = || {
            let mut p = NextPredictor::new(1, 0.0);
            p.observe(1);
            p.observe(7);
            p.observe(1);
            p.observe(5);
            p.observe(1);
            p
        };
        for _ in 0..16 {
            assert_eq!(build().predict(), Some(5));
        }
    }
}
