# Build-time helpers. The Rust crate itself needs only `cargo`; Python runs
# once here to AOT-compile the JAX/Pallas kernels into HLO-text artifacts
# that the Rust PJRT runtime loads (Python is never on the request path).

PYTHON ?= python3
ARTIFACTS := rust/artifacts

.PHONY: artifacts clean-artifacts

# AOT-lower every kernel variant into $(ARTIFACTS) (manifest.tsv is the
# sentinel the Rust side probes; without it the pjrt_roundtrip tests print
# their explicit skip marker instead of running).
artifacts:
	cd python && $(PYTHON) -m compile.aot --outdir ../$(ARTIFACTS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
